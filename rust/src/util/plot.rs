//! ASCII plotting: line plots for the aggregate-performance-over-time
//! figures (Figs 5, 6, 8) and violin plots for the score-distribution
//! figure (Fig 2). Series data is also exported as CSV for external
//! plotting; the ASCII rendering makes `tunetuner experiment figN` output
//! directly comparable to the paper's figures in a terminal.

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render multiple series into an ASCII grid with axes.
pub fn line_plot(title: &str, series: &[Series], width: usize, height: usize) -> String {
    const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        if x.is_finite() {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
        }
        if y.is_finite() {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !xmin.is_finite() || xmax <= xmin {
        xmax = xmin + 1.0;
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }
    let mut out = format!("{title}\n");
    for (ri, row) in grid.iter().enumerate() {
        let yval = ymax - (ymax - ymin) * ri as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>9} +{}\n{:>10} {:<.4}{}{:>.4}\n",
        "",
        "-".repeat(width),
        "",
        xmin,
        " ".repeat(width.saturating_sub(16)),
        xmax
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

/// A horizontal ASCII violin: density of `values` over its range, with
/// mean marker — one row per named distribution (Fig 2 style).
pub fn violin_plot(title: &str, dists: &[(String, Vec<f64>)], width: usize) -> String {
    const SHADES: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, vs) in dists {
        for &v in vs {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    if !lo.is_finite() || hi <= lo {
        return format!("{title}\n(no data)\n");
    }
    let name_w = dists.iter().map(|(n, _)| n.len()).max().unwrap_or(4).max(4);
    let mut out = format!("{title}\nrange [{lo:.3}, {hi:.3}]\n");
    for (name, vs) in dists {
        let mut bins = vec![0usize; width];
        let mut count = 0usize;
        let mut sum = 0.0;
        for &v in vs {
            if !v.is_finite() {
                continue;
            }
            let b = ((v - lo) / (hi - lo) * (width - 1) as f64).round() as usize;
            bins[b.min(width - 1)] += 1;
            count += 1;
            sum += v;
        }
        let peak = *bins.iter().max().unwrap_or(&1).max(&1);
        let mean_bin = if count > 0 {
            (((sum / count as f64) - lo) / (hi - lo) * (width - 1) as f64).round() as usize
        } else {
            0
        };
        let mut line = String::new();
        for (i, &b) in bins.iter().enumerate() {
            if i == mean_bin {
                line.push('|'); // mean marker
            } else {
                let shade = (b * (SHADES.len() - 1) + peak / 2) / peak;
                line.push(SHADES[shade]);
            }
        }
        out.push_str(&format!("{name:>name_w$} [{line}]\n"));
    }
    out
}

/// Export series as CSV: `x,<name1>,<name2>,...` on a shared x column
/// (series must share x values; missing points become empty cells).
pub fn series_csv(series: &[Series]) -> String {
    use std::collections::BTreeMap;
    let mut xs: Vec<f64> = Vec::new();
    for s in series {
        for &(x, _) in &s.points {
            if !xs.iter().any(|&e| e == x) {
                xs.push(x);
            }
        }
    }
    xs.sort_by(|a, b| a.total_cmp(b));
    let maps: Vec<BTreeMap<u64, f64>> = series
        .iter()
        .map(|s| {
            s.points
                .iter()
                .map(|&(x, y)| (x.to_bits(), y))
                .collect::<BTreeMap<_, _>>()
        })
        .collect();
    let mut out = String::from("x");
    for s in series {
        out.push(',');
        out.push_str(&s.name);
    }
    out.push('\n');
    for &x in &xs {
        out.push_str(&format!("{x}"));
        for m in &maps {
            out.push(',');
            if let Some(y) = m.get(&x.to_bits()) {
                out.push_str(&format!("{y}"));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_plot_renders() {
        let s = vec![
            Series {
                name: "a".into(),
                points: (0..50).map(|i| (i as f64, (i as f64 / 5.0).sin())).collect(),
            },
            Series {
                name: "b".into(),
                points: (0..50).map(|i| (i as f64, i as f64 / 50.0)).collect(),
            },
        ];
        let out = line_plot("demo", &s, 60, 12);
        assert!(out.contains("demo"));
        assert!(out.contains("* a"));
        assert!(out.contains("o b"));
        assert!(out.lines().count() > 12);
    }

    #[test]
    fn violin_renders_mean_marker() {
        let d = vec![("alg".to_string(), vec![0.0, 0.1, 0.2, 0.5, 0.5, 0.9])];
        let out = violin_plot("v", &d, 40);
        assert!(out.contains('|'));
        assert!(out.contains("alg"));
    }

    #[test]
    fn empty_plots_are_safe() {
        assert!(line_plot("t", &[], 10, 5).contains("no data"));
        assert!(violin_plot("t", &[], 10).contains("no data"));
    }

    #[test]
    fn csv_shared_axis() {
        let s = vec![
            Series { name: "a".into(), points: vec![(0.0, 1.0), (1.0, 2.0)] },
            Series { name: "b".into(), points: vec![(0.0, 3.0)] },
        ];
        let csv = series_csv(&s);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines[1], "0,1,3");
        assert_eq!(lines[2], "1,2,");
    }
}
