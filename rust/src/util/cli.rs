//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> [positional ...] [--flag] [--key value]
//! [--key=value]`. Unknown options are collected and reported by the
//! caller; `--help` handling is left to the binary.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    args.options
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // lint: allow(W03, reason = "peek guaranteed a value token follows")
                    let val = iter.next().unwrap();
                    args.options.insert(rest.to_string(), val);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|s| {
                s.parse()
                    // lint: allow(W03, reason = "CLI usage error; abort with a message")
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}"))
            })
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|s| {
                s.parse()
                    // lint: allow(W03, reason = "CLI usage error; abort with a message")
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}"))
            })
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|s| {
                s.parse()
                    // lint: allow(W03, reason = "CLI usage error; abort with a message")
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("tune gemm a100");
        assert_eq!(a.subcommand.as_deref(), Some("tune"));
        assert_eq!(a.positional, vec!["gemm", "a100"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse("tune --seed 42 --budget=0.95");
        assert_eq!(a.opt("seed"), Some("42"));
        assert_eq!(a.opt_f64("budget", 0.0), 0.95);
        assert_eq!(a.opt_usize("seed", 0), 42);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse("run --verbose --out dir --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert_eq!(a.opt("out"), Some("dir"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_or("name", "d"), "d");
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_u64("n", 9), 9);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("cmd --check");
        assert!(a.flag("check"));
        assert_eq!(a.opt("check"), None);
    }
}
