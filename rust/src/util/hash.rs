//! Fast non-cryptographic hashing for hot-path maps.
//!
//! std's default SipHash showed up at ~7% of the fig5 profile (the
//! config-index map keyed by `Vec<u16>` and the within-run evaluation
//! cache keyed by `usize`). This is an FxHash-style multiply-rotate
//! hasher: not DoS-resistant, which is fine for internal keys derived
//! from configuration indices.
//!
//! This module is the lint engine's W01 whitelist for std hash
//! collections: [`FastMap`]/[`FastSet`] pin a fixed-seed hasher, so
//! (given deterministic insertion) iteration order is reproducible
//! across runs — unlike std's randomly-seeded defaults.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style hasher.
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            // lint: allow(W03, reason = "chunks_exact(8) guarantees 8-byte slices")
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut word = [0u8; 8];
            word[..rest.len()].copy_from_slice(rest);
            self.mix(u64::from_le_bytes(word));
            self.mix(rest.len() as u64);
        }
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.mix(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.mix(v);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.mix(v as u64);
    }
}

/// HashMap with the fast hasher.
pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// HashSet with the fast hasher.
pub type FastSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_and_differs() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 10_000);
    }

    #[test]
    fn byte_slices_length_sensitive() {
        let hash = |b: &[u8]| {
            let mut h = FxHasher::default();
            h.write(b);
            h.finish()
        };
        assert_ne!(hash(b"abc"), hash(b"abcd"));
        assert_ne!(hash(b"abc\0"), hash(b"abc"));
        assert_eq!(hash(b"hello-world!!"), hash(b"hello-world!!"));
    }

    #[test]
    fn fastmap_works() {
        let mut m: FastMap<Vec<u16>, usize> = FastMap::default();
        for i in 0..100u16 {
            m.insert(vec![i, i + 1, i + 2], i as usize);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&vec![5, 6, 7]), Some(&5));
    }
}
