//! Statistics used across the framework: summary statistics and
//! percentiles (for baselines and budgets), the Kruskal–Wallis H test and
//! mutual-information scoring (for the hyperparameter sensitivity analysis
//! of Section IV-A of the paper).

/// Arithmetic mean; NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Median via sorting; NaN for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolation percentile (numpy's default), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

/// Percentile on pre-sorted data (ascending).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return f64::NAN;
    }
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Min/max helpers that skip NaN.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}
pub fn max(xs: &[f64]) -> f64 {
    xs.iter()
        .copied()
        .filter(|x| !x.is_nan())
        .fold(f64::NEG_INFINITY, f64::max)
}

/// Midranks (average ranks for ties), 1-based, as used by rank tests.
pub fn midranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // average of ranks i+1 ..= j+1
        let avg = (i + 1 + j + 1) as f64 / 2.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Kruskal–Wallis H test over `groups` of observations.
///
/// Returns `(H, p)` where p is the χ²(k-1) survival-function approximation.
/// Used for the hyperparameter sensitivity screen (which dropped PSO's `W`).
pub fn kruskal_wallis(groups: &[Vec<f64>]) -> (f64, f64) {
    let k = groups.len();
    let n: usize = groups.iter().map(|g| g.len()).sum();
    if k < 2 || n < 2 {
        return (0.0, 1.0);
    }
    let all: Vec<f64> = groups.iter().flatten().copied().collect();
    let ranks = midranks(&all);
    let mut h = 0.0;
    let mut offset = 0;
    for g in groups {
        let ni = g.len();
        if ni == 0 {
            continue;
        }
        let r_sum: f64 = ranks[offset..offset + ni].iter().sum();
        h += r_sum * r_sum / ni as f64;
        offset += ni;
    }
    let nf = n as f64;
    let mut h = 12.0 / (nf * (nf + 1.0)) * h - 3.0 * (nf + 1.0);

    // Tie correction.
    let mut sorted = all.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut tie_sum = 0.0;
    let mut i = 0;
    while i < sorted.len() {
        let mut j = i;
        while j + 1 < sorted.len() && sorted[j + 1] == sorted[i] {
            j += 1;
        }
        let t = (j - i + 1) as f64;
        tie_sum += t * t * t - t;
        i = j + 1;
    }
    let correction = 1.0 - tie_sum / (nf * nf * nf - nf);
    if correction > 0.0 {
        h /= correction;
    }
    let p = chi2_sf(h, (k - 1) as f64);
    (h, p)
}

/// χ² survival function via the regularized upper incomplete gamma.
pub fn chi2_sf(x: f64, dof: f64) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    gammainc_upper_reg(dof / 2.0, x / 2.0)
}

/// Regularized upper incomplete gamma Q(a, x), by series / continued fraction.
fn gammainc_upper_reg(a: f64, x: f64) -> f64 {
    if x < a + 1.0 {
        1.0 - gamma_series(a, x)
    } else {
        gamma_cf(a, x)
    }
}

fn ln_gamma(x: f64) -> f64 {
    // Lanczos approximation (g=7, n=9).
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut acc = COEF[0];
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            acc += c / (x + i as f64);
        }
        let t = x + 7.5;
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
    }
}

fn gamma_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-14 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

fn gamma_cf(a: f64, x: f64) -> f64 {
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Mutual information I(X; Y) in nats between a discrete X (group label per
/// observation) and a continuous Y, with Y discretized into `bins`
/// equal-frequency bins. Used to score hyperparameter sensitivity.
pub fn mutual_information(labels: &[usize], values: &[f64], bins: usize) -> f64 {
    assert_eq!(labels.len(), values.len());
    let n = values.len();
    if n == 0 || bins == 0 {
        return 0.0;
    }
    // Equal-frequency bin edges.
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let bin_of = |v: f64| -> usize {
        // rank of v within sorted data -> bin
        let pos = sorted.partition_point(|&s| s < v);
        (pos * bins / n).min(bins - 1)
    };
    let k = labels.iter().max().map(|&m| m + 1).unwrap_or(0);
    let mut joint = vec![0.0f64; k * bins];
    let mut px = vec![0.0f64; k];
    let mut py = vec![0.0f64; bins];
    for (&l, &v) in labels.iter().zip(values) {
        let b = bin_of(v);
        joint[l * bins + b] += 1.0;
        px[l] += 1.0;
        py[b] += 1.0;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for l in 0..k {
        for b in 0..bins {
            let pxy = joint[l * bins + b] / nf;
            if pxy > 0.0 {
                mi += pxy * (pxy / (px[l] / nf * py[b] / nf)).ln();
            }
        }
    }
    mi.max(0.0)
}

/// Pearson correlation; NaN if degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summaries() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-9);
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 4.0);
    }

    #[test]
    fn percentile_matches_numpy() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
        assert!((percentile(&xs, 95.0) - 38.5).abs() < 1e-12);
    }

    #[test]
    fn midranks_handle_ties() {
        let r = midranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
    }

    #[test]
    fn chi2_sf_known_values() {
        // chi2.sf(3.841, 1) ~ 0.05
        assert!((chi2_sf(3.841, 1.0) - 0.05).abs() < 1e-3);
        // chi2.sf(5.991, 2) ~ 0.05
        assert!((chi2_sf(5.991, 2.0) - 0.05).abs() < 1e-3);
        assert!((chi2_sf(0.0, 3.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kruskal_wallis_separates_groups() {
        // Clearly different groups -> small p.
        let g1: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let g2: Vec<f64> = (0..30).map(|i| 100.0 + i as f64).collect();
        let (h, p) = kruskal_wallis(&[g1, g2]);
        assert!(h > 10.0);
        assert!(p < 0.001);

        // Identical distributions -> high p.
        let g1: Vec<f64> = (0..40).map(|i| (i % 10) as f64).collect();
        let g2: Vec<f64> = (0..40).map(|i| ((i + 3) % 10) as f64).collect();
        let (_, p) = kruskal_wallis(&[g1, g2]);
        assert!(p > 0.2, "p={p}");
    }

    #[test]
    fn hand_crosscheck_kruskal() {
        // Hand-computed with midranks and tie correction:
        // groups [1,2,3,4], [2,3,4,5], [5,6,7,8] -> rank sums 14.5/22/41.5,
        // H_raw = 7.471, tie correction 0.98601 -> H = 7.577,
        // p = chi2.sf(7.577, 2) = exp(-7.577/2) = 0.0226.
        let (h, p) = kruskal_wallis(&[
            vec![1.0, 2.0, 3.0, 4.0],
            vec![2.0, 3.0, 4.0, 5.0],
            vec![5.0, 6.0, 7.0, 8.0],
        ]);
        assert!((h - 7.577).abs() < 0.01, "h={h}");
        assert!((p - 0.0226).abs() < 0.002, "p={p}");
    }

    #[test]
    fn mutual_information_signal_vs_noise() {
        // Values fully determined by label -> high MI; independent -> ~0.
        let labels: Vec<usize> = (0..400).map(|i| i % 4).collect();
        let dependent: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
        let mi_dep = mutual_information(&labels, &dependent, 4);
        let independent: Vec<f64> = (0..400).map(|i| (i * 7919 % 400) as f64).collect();
        let mi_ind = mutual_information(&labels, &independent, 4);
        assert!(mi_dep > 1.0, "mi_dep={mi_dep}");
        assert!(mi_ind < 0.1, "mi_ind={mi_ind}");
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }
}
