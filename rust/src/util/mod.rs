//! Offline substrates.
//!
//! The build environment has no crates.io access beyond the vendored set
//! shipped with the image (`xla`, `anyhow`, `flate2`, ...), so the usual
//! ecosystem crates (serde, rand, clap, criterion, log) are re-implemented
//! here at the scale this project needs.

pub mod json;
pub mod rng;
pub mod stats;
pub mod cli;
pub mod log;
pub mod compress;
pub mod fsio;
pub mod table;
pub mod plot;
pub mod hash;
