//! Leveled stderr logger with elapsed-time stamps.
//!
//! The level is set once at startup (from `--verbose` / `--quiet` or
//! `TUNETUNER_LOG`), then the `info!`/`debug!` macros are free when below
//! the level.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // default Info

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Initialize from the environment (TUNETUNER_LOG=debug|info|warn|error).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("TUNETUNER_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

/// Start-of-process instant for elapsed stamps.
pub fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !level_enabled(level) {
        return;
    }
    let elapsed = start_instant().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{elapsed:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(level_enabled(Level::Error));
        assert!(level_enabled(Level::Warn));
        assert!(!level_enabled(Level::Info));
        set_level(Level::Info);
        assert!(level_enabled(Level::Info));
        assert!(!level_enabled(Level::Debug));
    }
}
