//! # tunetuner — "Tuning the Tuner" in Rust + JAX + Pallas
//!
//! A production-grade auto-tuning framework with the paper's contribution —
//! generalized **hyperparameter tuning of the auto-tuner's own optimization
//! algorithms** — integrated as a first-class feature:
//!
//! * [`searchspace`] — constraint-based search-space engine (params,
//!   constraint expression language, enumeration, neighbor graphs).
//! * [`kernels`] — the four tuning problems of the paper (GEMM, 2D
//!   convolution, hotspot, dedispersion) as kernel specs with resource-usage
//!   feature extraction.
//! * [`gpu`] — the six simulated target devices (A100, A4000, A6000,
//!   MI250X, W6600, W7800).
//! * [`perfmodel`] — the analytical device model (Rust oracle of the L1
//!   Pallas kernel) and the measurement-noise model.
//! * [`runtime`] — PJRT runtime loading the AOT artifacts
//!   (`artifacts/perfmodel_b*.hlo.txt`) produced by `python/compile/aot.py`.
//! * [`runner`] — live runner (PJRT device model) and the paper's
//!   **simulation mode** (trace replay with simulated-clock accounting).
//! * [`dataset`] — brute-force driver, T1/T4 JSON formats, the columnar
//!   [`SimTable`](dataset::SimTable) behind simulation mode, the binary
//!   T4B cache sidecar, and the gzip-compressed FAIR benchmark hub.
//! * [`optimizers`] — ten optimization algorithms, each declaring a typed
//!   hyperparameter schema in a self-describing registry (the single
//!   source of truth for defaults, validation and the Table III/IV
//!   hyperparameter spaces), plus the shared CSR-walking local-search
//!   engine.
//! * [`methodology`] — baseline curves, the performance score `P` (Eq. 2)
//!   and its cross-search-space aggregation (Eq. 3).
//! * [`campaign`] — the orchestration API: a builder-style [`Campaign`]
//!   over a kernel×device matrix (or explicit `SpaceEval`s) executed on a
//!   persistent worker-pool [`Executor`], with [`Observer`] progress
//!   events and serde-stable [`CampaignResult`] envelopes. Every tuning
//!   run — exhaustive, meta, CLI — goes through it.
//! * [`hypertuning`] — exhaustive and meta-strategy hyperparameter tuning
//!   (Eq. 4), with the Table III / Table IV hyperparameter spaces, plus
//!   the full-registry sweep (`tunetuner sweep`): every grid-bearing
//!   optimizer hypertuned and compared default-vs-best in one versioned
//!   `tunetuner-sweep` envelope. A self-describing registry of budgeted
//!   meta-strategies (`random`, `tpe`, `halving`, `portfolio`) races
//!   against that sweep's optimum (`tunetuner metasweep`), reporting
//!   per-strategy recovery/regret/cost in a `tunetuner-metasweep`
//!   envelope.
//! * [`analysis`] — the self-dogfooded static-analysis engine behind
//!   `tunetuner lint`: a span-accurate token walk enforcing the repo's
//!   determinism (W01), persistence (W02), panic-discipline (W03),
//!   float-ordering (W04), and RNG-discipline (W05) invariants, with a
//!   justification-required inline suppression grammar and a versioned
//!   `tunetuner-lint` envelope.
//! * [`experiments`] — one regenerator per paper table/figure.
//! * [`error`] — the typed [`TuneError`] every fallible library API
//!   returns (the binary converts to `anyhow` at its boundary).
//! * [`faults`] — the deterministic fault-injection harness
//!   (`--inject-faults` / `TUNETUNER_FAULTS`) used to chaos-test job
//!   isolation, retry/quarantine, and crash-safe persistence.
//! * [`util`] — offline substrates (JSON, RNG, stats, CLI, logging,
//!   compression, crash-safe file staging, ASCII tables/plots).
//!
//! [`Campaign`]: campaign::Campaign
//! [`Executor`]: campaign::Executor
//! [`Observer`]: campaign::Observer
//! [`CampaignResult`]: campaign::CampaignResult
//! [`TuneError`]: error::TuneError

// Style lints this codebase deliberately deviates from: hot loops index
// buffers so evaluations can interleave with `&mut Tuning` borrows, the
// bitset/rank code does manual word math, and NaN-aware comparisons are
// spelled explicitly. Correctness, suspicious and perf lints stay on —
// CI enforces `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::neg_cmp_op_on_partial_ord,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::manual_range_contains,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

pub mod analysis;
pub mod error;
pub mod faults;
pub mod util;
pub mod searchspace;
pub mod kernels;
pub mod gpu;
pub mod perfmodel;
pub mod runtime;
pub mod runner;
pub mod dataset;
pub mod optimizers;
pub mod methodology;
pub mod campaign;
pub mod hypertuning;
pub mod experiments;
pub mod report;

pub use error::{Result, TuneError};

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
