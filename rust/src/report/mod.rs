//! Result rendering: every experiment writes its artifacts (ASCII
//! rendering + CSV data) into the results directory and echoes the ASCII
//! form to stdout, so `tunetuner experiment figN` output is directly
//! comparable to the paper's figure.

use crate::error::Result;
use crate::util::plot::{self, Series};
use crate::util::table::Table;
use std::path::{Path, PathBuf};

pub mod bench_trend;

/// A sink for experiment outputs.
pub struct Report {
    dir: PathBuf,
    /// Experiment id, e.g. "fig5".
    pub id: String,
}

impl Report {
    pub fn new(results_dir: &Path, id: &str) -> Report {
        Report {
            dir: results_dir.to_path_buf(),
            id: id.to_string(),
        }
    }

    fn write(&self, suffix: &str, contents: &str) -> Result<PathBuf> {
        let path = self.dir.join(format!("{}_{suffix}", self.id));
        crate::util::compress::write_string(&path, contents)?;
        Ok(path)
    }

    /// Emit a table: prints it and writes .txt + .csv.
    pub fn table(&self, table: &Table) -> Result<()> {
        let rendered = table.render();
        println!("{rendered}");
        self.write("table.txt", &rendered)?;
        self.write("data.csv", &table.to_csv())?;
        Ok(())
    }

    /// Emit an auxiliary table under a custom suffix (`{id}_{suffix}.txt`)
    /// so it does not clobber the report's main `*_table.txt` — used for
    /// the quarantined-legs failure table.
    pub fn table_as(&self, suffix: &str, table: &Table) -> Result<()> {
        let rendered = table.render();
        println!("{rendered}");
        self.write(&format!("{suffix}.txt"), &rendered)?;
        Ok(())
    }

    /// Emit a line plot: prints ASCII and writes .txt + .csv.
    pub fn lines(&self, title: &str, series: &[Series]) -> Result<()> {
        let rendered = plot::line_plot(title, series, 100, 24);
        println!("{rendered}");
        self.write("plot.txt", &rendered)?;
        self.write("series.csv", &plot::series_csv(series))?;
        Ok(())
    }

    /// Emit a violin plot: prints ASCII and writes .txt + per-dist CSV.
    pub fn violins(&self, title: &str, dists: &[(String, Vec<f64>)]) -> Result<()> {
        let rendered = plot::violin_plot(title, dists, 90);
        println!("{rendered}");
        self.write("violin.txt", &rendered)?;
        let mut csv = String::from("name,score\n");
        for (name, vals) in dists {
            for v in vals {
                csv.push_str(&format!("{name},{v}\n"));
            }
        }
        self.write("dist.csv", &csv)?;
        Ok(())
    }

    /// Free-form summary text (also printed).
    pub fn summary(&self, text: &str) -> Result<()> {
        println!("{text}");
        self.write("summary.txt", text)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_writes_files() {
        let dir = std::env::temp_dir().join(format!("tt_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let r = Report::new(&dir, "figX");
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["1".into()]);
        r.table(&t).unwrap();
        r.summary("hello").unwrap();
        assert!(dir.join("figX_table.txt").exists());
        assert!(dir.join("figX_data.csv").exists());
        assert!(dir.join("figX_summary.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
