//! Cross-PR benchmark trajectory (`tunetuner bench-trend`).
//!
//! Every PR's CI archives a `BENCH_<pr>.json` snapshot (see
//! `benches/bench_main.rs`). This module reads the accumulated artifacts,
//! renders the per-group trajectory across PRs, and flags groups whose
//! mean time regressed past a threshold relative to the previous
//! snapshot — the perf gate that keeps the replay stack honest.
//!
//! Only files named `BENCH_<digits>.json` participate (the numeric suffix
//! is the PR number and orders the trajectory); ad-hoc artifacts like
//! `BENCH_executor.json` or the working `BENCH.json` are ignored. Ratios
//! are computed per bench name over the *intersection* of names between
//! two consecutive snapshots, then combined per group as a geometric
//! mean, so adding or removing benches never fakes a speedup or a
//! regression. Snapshots should be compared like-for-like (CI compares
//! smoke runs against smoke runs); a mixed-mode trajectory is rendered
//! but flagged in the header.

use crate::error::{Context, Result};
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// One parsed `BENCH_<pr>.json` artifact.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// PR number parsed from the filename suffix.
    pub pr: u64,
    pub path: PathBuf,
    /// Smoke-mode run (CI) rather than a full sampling pass.
    pub smoke: bool,
    /// Bench name → mean seconds (finite, positive entries only).
    pub means: BTreeMap<String, f64>,
}

/// One group's change between two consecutive snapshots.
#[derive(Clone, Debug)]
pub struct GroupDelta {
    pub group: String,
    pub from_pr: u64,
    pub to_pr: u64,
    /// Bench names present in both snapshots (the comparison basis).
    pub common: usize,
    /// Geometric-mean `mean_s` ratio (to / from) over the common names;
    /// 1.0 = flat, above 1.0 = slower.
    pub ratio: f64,
}

impl GroupDelta {
    /// Regressed past `threshold_frac` (0.25 = 25% slower)?
    pub fn regressed(&self, threshold_frac: f64) -> bool {
        self.common > 0 && self.ratio > 1.0 + threshold_frac
    }
}

/// The group of a bench name: the prefix before the first '/'.
pub fn group_of(name: &str) -> &str {
    name.split('/').next().unwrap_or(name)
}

/// Parse a PR number out of a `BENCH_<digits>.json` filename.
pub fn pr_number(file_name: &str) -> Option<u64> {
    let digits = file_name.strip_prefix("BENCH_")?.strip_suffix(".json")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Parse one snapshot artifact.
pub fn parse_snapshot(pr: u64, path: &Path) -> Result<Snapshot> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = json::parse(&text)
        .with_context(|| format!("parsing {}", path.display()))?;
    if j.get("schema").and_then(Json::as_str) != Some("tunetuner-bench") {
        crate::bail!("{}: not a tunetuner-bench artifact", path.display());
    }
    let mut means = BTreeMap::new();
    for b in j.get("benches").and_then(Json::as_arr).unwrap_or(&[]) {
        if let (Some(name), Some(mean_s)) = (
            b.get("name").and_then(Json::as_str),
            b.get("mean_s").and_then(Json::as_f64),
        ) {
            if mean_s.is_finite() && mean_s > 0.0 {
                means.insert(name.to_string(), mean_s);
            }
        }
    }
    Ok(Snapshot {
        pr,
        path: path.to_path_buf(),
        smoke: j.get("smoke").and_then(Json::as_bool).unwrap_or(false),
        means,
    })
}

/// Find and parse every `BENCH_<pr>.json` in `dir`, ordered by PR number.
pub fn discover(dir: &Path) -> Result<Vec<Snapshot>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    let mut found: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries {
        let entry = entry?;
        if let Some(pr) = pr_number(&entry.file_name().to_string_lossy()) {
            found.push((pr, entry.path()));
        }
    }
    found.sort();
    found
        .into_iter()
        .map(|(pr, path)| parse_snapshot(pr, &path))
        .collect()
}

fn geomean(ratios: &[f64]) -> f64 {
    if ratios.is_empty() {
        return f64::NAN;
    }
    (ratios.iter().map(|r| r.ln()).sum::<f64>() / ratios.len() as f64).exp()
}

/// Per-group deltas between two consecutive snapshots, over the
/// intersection of their bench names. Groups with no common names are
/// omitted (nothing comparable — a brand-new group cannot regress).
pub fn group_deltas(prev: &Snapshot, latest: &Snapshot) -> Vec<GroupDelta> {
    let mut by_group: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for (name, &m_new) in &latest.means {
        if let Some(&m_old) = prev.means.get(name) {
            by_group.entry(group_of(name)).or_default().push(m_new / m_old);
        }
    }
    by_group
        .into_iter()
        .map(|(group, ratios)| GroupDelta {
            group: group.to_string(),
            from_pr: prev.pr,
            to_pr: latest.pr,
            common: ratios.len(),
            ratio: geomean(&ratios),
        })
        .collect()
}

/// The gate input: deltas between the last two snapshots (empty when
/// fewer than two snapshots exist — nothing to compare, nothing fails).
pub fn latest_deltas(snapshots: &[Snapshot]) -> Vec<GroupDelta> {
    match snapshots {
        [.., prev, latest] => group_deltas(prev, latest),
        _ => Vec::new(),
    }
}

fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        "-".to_string()
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Render the per-group trajectory table. Cells are the geometric mean of
/// a group's bench times within one snapshot (absolute, informational);
/// the `vs prev` column is the intersection-based [`GroupDelta`] ratio
/// for the final snapshot pair, annotated when past `threshold_frac`.
pub fn render(snapshots: &[Snapshot], threshold_frac: f64) -> String {
    if snapshots.is_empty() {
        return "bench-trend: no BENCH_<pr>.json snapshots found\n".to_string();
    }
    let mixed = snapshots.iter().any(|s| s.smoke) && snapshots.iter().any(|s| !s.smoke);
    let mut out = format!(
        "bench trend: {} snapshot(s), PR {} .. PR {}, threshold {:.0}%\n",
        snapshots.len(),
        snapshots[0].pr,
        snapshots[snapshots.len() - 1].pr,
        threshold_frac * 100.0
    );
    if mixed {
        out.push_str("warning: mixing smoke and full snapshots — ratios are indicative only\n");
    }
    let groups: BTreeSet<&str> = snapshots
        .iter()
        .flat_map(|s| s.means.keys().map(|n| group_of(n)))
        .collect();
    let deltas = latest_deltas(snapshots);

    out.push_str(&format!("{:<12}", "group"));
    for s in snapshots {
        let tag = format!("PR{}{}", s.pr, if s.smoke { "*" } else { "" });
        out.push_str(&format!(" {tag:>10}"));
    }
    out.push_str(&format!(" {:>10}\n", "vs prev"));
    for group in groups {
        out.push_str(&format!("{group:<12}"));
        for s in snapshots {
            let times: Vec<f64> = s
                .means
                .iter()
                .filter(|(n, _)| group_of(n) == group)
                .map(|(_, &m)| m)
                .collect();
            let cell = if times.is_empty() {
                "-".to_string()
            } else {
                fmt_secs(geomean(&times))
            };
            out.push_str(&format!(" {cell:>10}"));
        }
        match deltas.iter().find(|d| d.group == group) {
            Some(d) => {
                let mark = if d.regressed(threshold_frac) {
                    "  REGRESSED"
                } else {
                    ""
                };
                out.push_str(&format!(" {:>9.2}x{mark}\n", d.ratio));
            }
            None => out.push_str(&format!(" {:>10}\n", "new")),
        }
    }
    if snapshots.iter().any(|s| s.smoke) {
        out.push_str("(* = smoke-mode snapshot)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_snapshot(dir: &Path, name: &str, smoke: bool, benches: &[(&str, f64)]) {
        let rows: Vec<String> = benches
            .iter()
            .map(|(n, m)| {
                format!(
                    "{{\"name\":\"{n}\",\"group\":\"{}\",\"mean_s\":{m},\
                     \"stddev_frac\":0.01,\"iters\":5,\"items_per_s\":null}}",
                    group_of(n)
                )
            })
            .collect();
        let body = format!(
            "{{\"schema\":\"tunetuner-bench\",\"schema_version\":1,\
             \"smoke\":{smoke},\"filter\":null,\"generated_unix\":1.0,\
             \"benches\":[{}]}}",
            rows.join(",")
        );
        crate::util::fsio::atomic_write(&dir.join(name), body.as_bytes()).unwrap();
    }

    fn fixture_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tt_bench_trend_{}_{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn pr_number_parses_only_numeric_suffixes() {
        assert_eq!(pr_number("BENCH_6.json"), Some(6));
        assert_eq!(pr_number("BENCH_42.json"), Some(42));
        assert_eq!(pr_number("BENCH_executor.json"), None);
        assert_eq!(pr_number("BENCH.json"), None);
        assert_eq!(pr_number("BENCH_.json"), None);
        assert_eq!(pr_number("BENCH_6.json.bak"), None);
    }

    #[test]
    fn discover_orders_by_pr_and_skips_nonnumeric() {
        let dir = fixture_dir("discover");
        write_snapshot(&dir, "BENCH_5.json", true, &[("sim/a", 1e-3)]);
        write_snapshot(&dir, "BENCH_4.json", true, &[("sim/a", 1e-3)]);
        write_snapshot(&dir, "BENCH_executor.json", true, &[("executor/x", 1e-3)]);
        write_snapshot(&dir, "BENCH.json", true, &[("sim/a", 1e-3)]);
        let snaps = discover(&dir).unwrap();
        assert_eq!(snaps.iter().map(|s| s.pr).collect::<Vec<_>>(), vec![4, 5]);
        assert!(snaps[0].smoke);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The self-test the CI gate runs: an injected synthetic regression
    /// must be flagged, and the healthy prefix must not be.
    #[test]
    fn injected_regression_is_flagged() {
        let dir = fixture_dir("gate");
        write_snapshot(
            &dir,
            "BENCH_4.json",
            true,
            &[("sim/a", 1.0e-3), ("sim/b", 2.0e-3), ("cache/x", 5.0e-4)],
        );
        write_snapshot(
            &dir,
            "BENCH_5.json",
            true,
            &[("sim/a", 1.02e-3), ("sim/b", 1.9e-3), ("cache/x", 5.1e-4)],
        );
        // PR 6: sim/a 2x slower — the sim group regresses; cache stays
        // flat; a brand-new group cannot regress.
        write_snapshot(
            &dir,
            "BENCH_6.json",
            true,
            &[
                ("sim/a", 2.04e-3),
                ("sim/b", 1.9e-3),
                ("cache/x", 5.1e-4),
                ("fresh/y", 1.0e-3),
            ],
        );
        let snaps = discover(&dir).unwrap();
        assert_eq!(snaps.len(), 3);

        // Healthy pair: nothing past 25%.
        let healthy = group_deltas(&snaps[0], &snaps[1]);
        assert!(healthy.iter().all(|d| !d.regressed(0.25)), "{healthy:?}");

        // Latest pair: exactly the sim group regresses.
        let deltas = latest_deltas(&snaps);
        let bad: Vec<&GroupDelta> =
            deltas.iter().filter(|d| d.regressed(0.25)).collect();
        assert_eq!(bad.len(), 1, "{deltas:?}");
        assert_eq!(bad[0].group, "sim");
        assert_eq!(bad[0].common, 2);
        // geomean(2.0, 1.0) = sqrt(2).
        assert!((bad[0].ratio - 2.0f64.sqrt()).abs() < 1e-9);
        // cache is flat; the new group is absent from the deltas.
        assert!(deltas.iter().any(|d| d.group == "cache" && !d.regressed(0.25)));
        assert!(!deltas.iter().any(|d| d.group == "fresh"));

        let rendered = render(&snaps, 0.25);
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("sim"), "{rendered}");
        assert!(rendered.contains("new"), "{rendered}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_snapshot_has_no_gate_input() {
        let dir = fixture_dir("single");
        write_snapshot(&dir, "BENCH_6.json", false, &[("sim/a", 1e-3)]);
        let snaps = discover(&dir).unwrap();
        assert!(latest_deltas(&snaps).is_empty());
        let rendered = render(&snaps, 0.25);
        assert!(rendered.contains("PR 6"), "{rendered}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_foreign_schema() {
        let dir = fixture_dir("schema");
        crate::util::fsio::atomic_write(&dir.join("BENCH_9.json"), b"{\"schema\":\"other\"}")
            .unwrap();
        assert!(discover(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
