//! The device-model evaluation engine.
//!
//! Two backends:
//! * [`EngineBackend::Pjrt`] — the real path: HLO artifacts compiled on the
//!   PJRT CPU client (one executable per batch size), batches padded to the
//!   smallest artifact that fits.
//! * [`EngineBackend::Native`] — the Rust oracle (`perfmodel::analytical`),
//!   used by unit tests and as a no-artifacts fallback; bit-compatible with
//!   the HLO path to ~1 ulp (asserted by integration tests).
//!
//! The engine is `Send + Sync`; PJRT executions are serialized through a
//! mutex (the CPU client is not thread-safe for concurrent executes), while
//! native evaluations run lock-free.

use crate::perfmodel::analytical;
#[cfg(feature = "pjrt")]
use crate::perfmodel::contract;
use crate::perfmodel::contract::{NUM_DEVICE, NUM_FEATURES};
#[cfg(feature = "pjrt")]
use crate::bail;
#[cfg(feature = "pjrt")]
use crate::error::Context;
use crate::error::{Result, TuneError};
#[cfg(feature = "pjrt")]
use crate::util::json;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Evaluation triple per configuration (see `model.measure_batch`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measurement {
    /// True (noise-free) kernel time in seconds.
    pub time: f32,
    /// Cold first-observation time (warmup drift).
    pub t_cold: f32,
    /// Steady-state best-case time.
    pub t_hot: f32,
}

/// Which evaluation path to use.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineBackend {
    Pjrt,
    Native,
}

#[cfg(feature = "pjrt")]
struct PjrtState {
    // Kept alive for the lifetime of the executables (PJRT requires it).
    #[allow(dead_code)]
    client: xla::PjRtClient,
    /// (batch_size, executable), ascending by batch size.
    executables: Vec<(usize, xla::PjRtLoadedExecutable)>,
}

// The xla crate's client handles are raw pointers without Send/Sync
// markers; all access is serialized through the mutex in `Engine`.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtState {}

// XLA runtime failures surface as the typed `Engine` error class.
#[cfg(feature = "pjrt")]
impl From<xla::Error> for TuneError {
    fn from(e: xla::Error) -> TuneError {
        TuneError::Engine(e.to_string())
    }
}

/// Placeholder so `Engine`'s layout is feature-independent; the `pjrt`
/// field is always `None` without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
#[allow(dead_code)]
struct PjrtState {}

/// Batched device-model evaluator.
pub struct Engine {
    backend: EngineBackend,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    pjrt: Option<Mutex<PjrtState>>,
    /// Cumulative count of configurations evaluated (for perf accounting).
    evals: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Native-oracle engine (no artifacts needed).
    pub fn native() -> Engine {
        Engine {
            backend: EngineBackend::Native,
            pjrt: None,
            evals: Default::default(),
        }
    }

    /// PJRT engine from an artifacts directory (validates contract.json).
    /// Without the `pjrt` feature (which needs the vendored `xla` crate)
    /// this always errs, and `Engine::auto` falls back to the native
    /// oracle.
    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt(artifacts_dir: &Path) -> Result<Engine> {
        let _ = artifacts_dir;
        Err(TuneError::Engine(
            "built without the `pjrt` feature; add the vendored `xla` crate \
             to [dependencies] in Cargo.toml and rebuild with --features pjrt"
                .into(),
        ))
    }

    /// PJRT engine from an artifacts directory (validates contract.json).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: &Path) -> Result<Engine> {
        let contract_path = artifacts_dir.join("contract.json");
        let text = std::fs::read_to_string(&contract_path)
            .with_context(|| format!("read {}", contract_path.display()))?;
        let parsed = json::parse(&text).context("parse contract.json")?;
        contract::validate_contract(&parsed)
            .context("artifact contract does not match this binary")?;

        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut executables = Vec::new();
        for &n in &contract::BATCH_SIZES {
            let path = artifacts_dir.join(format!("perfmodel_b{n}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            executables.push((n, exe));
        }
        if executables.is_empty() {
            bail!(
                "no perfmodel_b*.hlo.txt artifacts in {} (run `make artifacts`)",
                artifacts_dir.display()
            );
        }
        executables.sort_by_key(|(n, _)| *n);
        Ok(Engine {
            backend: EngineBackend::Pjrt,
            pjrt: Some(Mutex::new(PjrtState {
                client,
                executables,
            })),
            evals: Default::default(),
        })
    }

    /// Auto-detect: PJRT if artifacts are present, else native (logged).
    pub fn auto(artifacts_dir: &Path) -> Engine {
        match Engine::pjrt(artifacts_dir) {
            Ok(e) => e,
            Err(err) => {
                crate::log_warn!(
                    "PJRT engine unavailable ({err:#}); falling back to native oracle"
                );
                Engine::native()
            }
        }
    }

    /// Default artifacts directory: `$TUNETUNER_ARTIFACTS` or `./artifacts`.
    pub fn default_artifacts_dir() -> PathBuf {
        std::env::var("TUNETUNER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn backend(&self) -> EngineBackend {
        self.backend
    }

    /// Total configurations evaluated so far.
    pub fn eval_count(&self) -> u64 {
        self.evals.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Evaluate the device model for a batch of feature vectors.
    pub fn measure(
        &self,
        features: &[[f32; NUM_FEATURES]],
        device: &[f32; NUM_DEVICE],
    ) -> Result<Vec<Measurement>> {
        self.evals
            .fetch_add(features.len() as u64, std::sync::atomic::Ordering::Relaxed);
        match self.backend {
            EngineBackend::Native => Ok(features
                .iter()
                .map(|f| {
                    let (t, c, h) = analytical::measure_triple(f, device);
                    Measurement {
                        time: t,
                        t_cold: c,
                        t_hot: h,
                    }
                })
                .collect()),
            EngineBackend::Pjrt => self.measure_pjrt(features, device),
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn measure_pjrt(
        &self,
        _features: &[[f32; NUM_FEATURES]],
        _device: &[f32; NUM_DEVICE],
    ) -> Result<Vec<Measurement>> {
        Err(TuneError::Engine(
            "PJRT backend selected but built without the `pjrt` feature".into(),
        ))
    }

    #[cfg(feature = "pjrt")]
    fn measure_pjrt(
        &self,
        features: &[[f32; NUM_FEATURES]],
        device: &[f32; NUM_DEVICE],
    ) -> Result<Vec<Measurement>> {
        // lint: allow(W03, reason = "measure requires a loaded PJRT backend")
        let state = self.pjrt.as_ref().unwrap().lock().unwrap();
        let mut out = Vec::with_capacity(features.len());
        let mut offset = 0usize;
        while offset < features.len() {
            let remaining = features.len() - offset;
            // Smallest artifact that fits, or the largest one for chunking.
            let (batch, exe) = state
                .executables
                .iter()
                .find(|(n, _)| *n >= remaining)
                .or_else(|| state.executables.last())
                // lint: allow(W03, reason = "executables is non-empty once loaded")
                .unwrap();
            let take = remaining.min(*batch);
            let chunk = &features[offset..offset + take];

            // Pack + pad the feature matrix.
            let mut flat = vec![0f32; batch * NUM_FEATURES];
            for (i, f) in chunk.iter().enumerate() {
                flat[i * NUM_FEATURES..(i + 1) * NUM_FEATURES].copy_from_slice(f);
            }
            // Padding rows must be *valid-shaped* to avoid NaNs; zeros are
            // fine (they produce INVALID_TIME) since we slice them off.
            let f_lit = xla::Literal::vec1(&flat)
                .reshape(&[*batch as i64, NUM_FEATURES as i64])?;
            let d_lit = xla::Literal::vec1(device);

            let result = exe.execute::<xla::Literal>(&[f_lit, d_lit])?[0][0]
                .to_literal_sync()?;
            let tuple = result.to_tuple()?;
            if tuple.len() != 3 {
                bail!("expected 3 outputs from measure_batch, got {}", tuple.len());
            }
            let times = tuple[0].to_vec::<f32>()?;
            let colds = tuple[1].to_vec::<f32>()?;
            let hots = tuple[2].to_vec::<f32>()?;
            for i in 0..take {
                out.push(Measurement {
                    time: times[i],
                    t_cold: colds[i],
                    t_hot: hots[i],
                });
            }
            offset += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;
    use crate::kernels;

    #[test]
    fn native_engine_matches_oracle() {
        let engine = Engine::native();
        let k = kernels::synthetic::build().unwrap();
        let feats = k.all_features();
        let d = A100.to_vector();
        let ms = engine.measure(&feats, &d).unwrap();
        assert_eq!(ms.len(), feats.len());
        for (f, m) in feats.iter().zip(&ms) {
            assert_eq!(m.time, analytical::predict_time(f, &d));
        }
        assert_eq!(engine.eval_count(), feats.len() as u64);
    }

    #[test]
    fn native_chunking_any_size() {
        let engine = Engine::native();
        let d = A100.to_vector();
        for n in [1usize, 3, 255, 300] {
            let feats = vec![[1.0f32; NUM_FEATURES]; n];
            assert_eq!(engine.measure(&feats, &d).unwrap().len(), n);
        }
    }
}
