//! PJRT runtime: load and execute the AOT artifacts.
//!
//! `python/compile/aot.py` lowers the L2 `measure_batch` graph (which
//! contains the L1 Pallas device-model kernel) to HLO text once per batch
//! size. This module loads those artifacts, compiles them on the PJRT CPU
//! client, and exposes batched evaluation to the Rust hot path. Python is
//! never involved at runtime.

pub mod engine;

pub use engine::{Engine, EngineBackend};
