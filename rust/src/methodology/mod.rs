//! The autotuning-methodology scoring framework (Section III-B).
//!
//! Implements the paper's statistically robust performance score:
//!
//! 1. [`baseline`] — the calibrated random-search baseline, computed
//!    analytically from the full value distribution via order statistics
//!    (no Monte-Carlo noise), mapped onto a time axis through the mean
//!    per-evaluation cost.
//! 2. The **budget**: the time at which the baseline reaches a cutoff
//!    percentile (default 95%) of the median→optimum distance.
//! 3. [`curve`] — performance-over-time curves of an algorithm's repeated
//!    runs, sampled at fixed equidistant time points relative to the
//!    budget.
//! 4. [`score`] — Eq. (2): `P_t = (S_baseline(t) - F_t) / (S_baseline(t)
//!    - S_opt)`, so 0 = baseline parity, 1 = optimum found immediately.
//! 5. [`aggregate`] — Eq. (3): mean over search spaces at each sampling
//!    point, then mean over sampling points → the scalar score the
//!    hyperparameter tuner maximizes (Eq. 4).

pub mod baseline;
pub mod curve;
pub mod score;
pub mod aggregate;

pub use aggregate::{evaluate_algorithm, score_campaign, AggregateResult, SpaceEval};
pub use baseline::Baseline;
pub use curve::PerformanceCurve;

/// Default cutoff percentile for the budget (the paper: "typically
/// somewhere around 95%").
pub const DEFAULT_CUTOFF: f64 = 0.95;

/// Default number of equidistant sampling points per curve.
pub const DEFAULT_POINTS: usize = 50;

/// Default repeats during hyperparameter tuning (paper: 25).
pub const TUNING_REPEATS: usize = 25;

/// Default repeats for re-evaluation comparisons (paper: 100).
pub const EVAL_REPEATS: usize = 100;
