//! Eq. (3): aggregation of performance scores across search spaces, and
//! the central `evaluate_algorithm` entry point the hyperparameter tuner
//! maximizes (Eq. 4).
//!
//! `evaluate_algorithm` is a thin wrapper over
//! [`crate::campaign::Campaign`]: the campaign owns job decomposition,
//! deterministic seeding and the persistent executor pool; this module
//! owns the scoring (prepared [`SpaceEval`]s and the Eq. 2/Eq. 3 math).

use super::baseline::Baseline;
use super::curve::{sampling_times, PerformanceCurve};
use super::score::score_at;
use crate::dataset::cache::CacheData;
use crate::error::Result;
use crate::optimizers::HyperParams;
use crate::runner::Trace;
use crate::searchspace::SearchSpace;
use std::sync::Arc;

/// A search space prepared for scoring: budget, sampling times and
/// baseline values precomputed from its brute-force cache.
#[derive(Clone)]
pub struct SpaceEval {
    pub label: String,
    pub space: Arc<SearchSpace>,
    pub cache: Arc<CacheData>,
    pub budget_seconds: f64,
    pub optimum: f64,
    /// Equidistant sampling times in (0, budget].
    pub times: Vec<f64>,
    /// Baseline value at each sampling time.
    pub baseline_values: Vec<f64>,
}

impl SpaceEval {
    /// Prepare a space: compute the cutoff budget and baseline curve.
    pub fn new(
        space: Arc<SearchSpace>,
        cache: Arc<CacheData>,
        cutoff: f64,
        points: usize,
    ) -> SpaceEval {
        let mut baseline = Baseline::new(&cache);
        let budget_seconds = baseline.budget_seconds(cutoff);
        let times = sampling_times(budget_seconds, points);
        // One multi-accumulator pass over the value distribution serves
        // the whole sampling grid (bit-identical to per-point calls).
        let baseline_values = baseline.values_at_times(&times);
        SpaceEval {
            label: format!("{}@{}", cache.kernel, cache.device),
            optimum: baseline.optimum,
            space,
            cache,
            budget_seconds,
            times,
            baseline_values,
        }
    }

    /// Score one set of repeated traces on this space (Eq. 2 per point).
    pub fn score_traces(&self, traces: &[Trace]) -> Vec<f64> {
        // The fallback is indexed by sampling point, so it cannot drift
        // out of register with the baseline (the old closure counted its
        // own calls and silently depended on being called exactly once
        // per point, in order).
        let curve = PerformanceCurve::from_traces(traces, &self.times, |i| {
            self.baseline_values[i]
        });
        curve
            .values
            .iter()
            .zip(&self.baseline_values)
            .map(|(&v, &b)| score_at(b, v, self.optimum))
            .collect()
    }
}

/// Score a whole campaign's traces in one call: `traces` holds
/// `spaces.len() * repeats` runs grouped by space in campaign job order.
/// Each space's repeat-group is scored against its precomputed baseline
/// curve — the per-space Eq. (2) score matrix a campaign aggregates.
pub fn score_campaign(
    spaces: &[SpaceEval],
    traces: &[Trace],
    repeats: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(
        traces.len(),
        spaces.len() * repeats,
        "traces must be grouped per space"
    );
    spaces
        .iter()
        .enumerate()
        .map(|(s, se)| se.score_traces(&traces[s * repeats..(s + 1) * repeats]))
        .collect()
}

/// The outcome of evaluating one (algorithm, hyperparameters) pair.
#[derive(Clone, Debug)]
pub struct AggregateResult {
    /// Eq. (2) score per space per sampling point: `[space][t]`.
    pub per_space_scores: Vec<Vec<f64>>,
    /// Mean over spaces at each (relative) sampling point.
    pub aggregate_curve: Vec<f64>,
    /// Eq. (3): mean of the aggregate curve — the scalar score.
    pub score: f64,
}

impl AggregateResult {
    /// Eq. (3) from per-space Eq. (2) curves: mean over spaces at each
    /// sampling point, then mean over points.
    pub fn from_per_space_scores(per_space_scores: Vec<Vec<f64>>) -> AggregateResult {
        let points = per_space_scores[0].len();
        let aggregate_curve: Vec<f64> = (0..points)
            .map(|t| {
                per_space_scores.iter().map(|s| s[t]).sum::<f64>()
                    / per_space_scores.len() as f64
            })
            .collect();
        let score = crate::util::stats::mean(&aggregate_curve);
        AggregateResult {
            per_space_scores,
            aggregate_curve,
            score,
        }
    }

    /// Mean score per space (for the per-space impact figures 4 and 7).
    pub fn per_space_means(&self) -> Vec<f64> {
        self.per_space_scores
            .iter()
            .map(|s| crate::util::stats::mean(s))
            .collect()
    }
}

/// Run `repeats` simulated tuning runs of `algo(hp)` on every space and
/// aggregate the scores (Eq. 3). A thin wrapper over
/// [`crate::campaign::Campaign`] on the process-wide executor: runs are
/// parallelized over (space, repeat) pairs with seeds deterministic per
/// (seed, space, repeat), so results are reproducible regardless of
/// thread scheduling.
pub fn evaluate_algorithm(
    algo: &str,
    hp: &HyperParams,
    spaces: &[SpaceEval],
    repeats: usize,
    seed: u64,
) -> Result<AggregateResult> {
    let result = crate::campaign::Campaign::new(algo)
        .hyperparams(hp.clone())
        .space_evals(spaces.to_vec())
        .repeats(repeats)
        .seed(seed)
        .run()?;
    Ok(result.aggregate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::{A100, W7800};
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::{LiveRunner, TracePoint};
    use crate::runtime::Engine;
    use std::sync::OnceLock;

    fn spaces() -> &'static Vec<SpaceEval> {
        static SPACES: OnceLock<Vec<SpaceEval>> = OnceLock::new();
        SPACES.get_or_init(|| {
            let engine = Arc::new(Engine::native());
            [&A100, &W7800]
                .iter()
                .map(|dev| {
                    let kernel = kernels::kernel_by_name("synthetic").unwrap();
                    let mut live = LiveRunner::new(
                        kernels::kernel_by_name("synthetic").unwrap(),
                        dev,
                        Arc::clone(&engine),
                        NoiseModel::default(),
                        42,
                    );
                    let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                    SpaceEval::new(kernel.space_arc(), cache, 0.95, 20)
                })
                .collect()
        })
    }

    /// The methodology's calibration property: random search must score
    /// ~0 against the analytic random-search baseline.
    #[test]
    fn random_search_scores_near_zero() {
        let r = evaluate_algorithm(
            "random_search",
            &HyperParams::new(),
            spaces(),
            60,
            7,
        )
        .unwrap();
        assert!(
            r.score.abs() < 0.12,
            "random search should match the baseline, got {}",
            r.score
        );
    }

    /// A real optimizer must beat random search on the aggregate score.
    /// (Absolute scores with *default* hyperparameters are modest — that
    /// is the paper's premise; the hypertuning experiments quantify the
    /// lift. Here we only assert the scoring separates the methods.)
    #[test]
    fn good_optimizer_beats_random() {
        let rs = evaluate_algorithm("random_search", &HyperParams::new(), spaces(), 25, 7)
            .unwrap();
        let pso = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 25, 7).unwrap();
        assert!(
            pso.score > rs.score + 0.05,
            "pso {} vs random {}",
            pso.score,
            rs.score
        );
    }

    /// Deterministic despite parallel scheduling.
    #[test]
    fn deterministic_across_runs() {
        let a = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 10, 3).unwrap();
        let b = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 10, 3).unwrap();
        assert_eq!(a.score, b.score);
        assert_eq!(a.aggregate_curve, b.aggregate_curve);
    }

    #[test]
    fn per_space_shapes() {
        let r = evaluate_algorithm("mls", &HyperParams::new(), spaces(), 5, 1).unwrap();
        assert_eq!(r.per_space_scores.len(), 2);
        assert_eq!(r.per_space_scores[0].len(), 20);
        assert_eq!(r.aggregate_curve.len(), 20);
        assert_eq!(r.per_space_means().len(), 2);
        // Score within plausible bounds.
        assert!(r.score > -1.5 && r.score < 1.0);
    }

    #[test]
    fn unknown_algorithm_rejected() {
        assert!(evaluate_algorithm("nope", &HyperParams::new(), spaces(), 2, 1).is_err());
    }

    /// Regression for the index-coupled fallback: a trace whose first
    /// result lands *after* the first sampling points must score exactly
    /// 0 (baseline parity) at every point it hasn't reached — for every
    /// sampling point, not just the first.
    #[test]
    fn late_starting_trace_scores_baseline_until_first_result() {
        let se = &spaces()[0];
        // One evaluation, landing 60% into the budget with the optimum.
        let late_clock = se.budget_seconds * 0.6;
        let trace = Trace {
            points: vec![TracePoint {
                config: 0,
                value: se.optimum,
                clock: late_clock,
                cached: false,
            }],
            elapsed: late_clock,
            unique_evals: 1,
        };
        let scores = se.score_traces(&[trace]);
        assert_eq!(scores.len(), se.times.len());
        for (i, (&t, &s)) in se.times.iter().zip(&scores).enumerate() {
            if t < late_clock {
                // Fallback = baseline value at *this* point => score 0.
                assert!(
                    s.abs() < 1e-12,
                    "point {i} (t={t:.2}) should be baseline parity, got {s}"
                );
            } else {
                // After the optimum lands the score is exactly 1.
                assert!(
                    (s - 1.0).abs() < 1e-12,
                    "point {i} (t={t:.2}) should be 1.0, got {s}"
                );
            }
        }
    }

    /// `score_campaign` is exactly the per-space `score_traces` chunking.
    #[test]
    fn score_campaign_matches_per_space_chunks() {
        let ses = spaces();
        let repeats = 3usize;
        let mut traces: Vec<Trace> = Vec::new();
        for (s, se) in ses.iter().enumerate() {
            for r in 0..repeats {
                let clock = se.budget_seconds * (0.2 + 0.2 * r as f64);
                traces.push(Trace {
                    points: vec![TracePoint {
                        config: s,
                        value: se.optimum * (1.0 + 0.1 * r as f64),
                        clock,
                        cached: false,
                    }],
                    elapsed: clock,
                    unique_evals: 1,
                });
            }
        }
        let batch = score_campaign(ses, &traces, repeats);
        assert_eq!(batch.len(), ses.len());
        for (s, se) in ses.iter().enumerate() {
            let scalar = se.score_traces(&traces[s * repeats..(s + 1) * repeats]);
            assert_eq!(batch[s].len(), scalar.len());
            for (a, b) in batch[s].iter().zip(&scalar) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn from_per_space_scores_matches_manual_math() {
        let agg = AggregateResult::from_per_space_scores(vec![
            vec![0.0, 0.4, 0.8],
            vec![0.2, 0.6, 1.0],
        ]);
        assert_eq!(agg.aggregate_curve, vec![0.1, 0.5, 0.9]);
        assert!((agg.score - 0.5).abs() < 1e-12);
        assert_eq!(agg.per_space_means(), vec![0.4, 0.6]);
    }
}
