//! Eq. (3): aggregation of performance scores across search spaces, and
//! the central `evaluate_algorithm` entry point the hyperparameter tuner
//! maximizes (Eq. 4).

use super::baseline::Baseline;
use super::curve::{sampling_times, PerformanceCurve};
use super::score::score_at;
use crate::dataset::cache::CacheData;
use crate::optimizers::{self, HyperParams};
use crate::runner::{Budget, SimulationRunner, Trace, Tuning};
use crate::searchspace::SearchSpace;
use crate::util::rng::{mix64, Rng};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// A search space prepared for scoring: budget, sampling times and
/// baseline values precomputed from its brute-force cache.
#[derive(Clone)]
pub struct SpaceEval {
    pub label: String,
    pub space: Arc<SearchSpace>,
    pub cache: Arc<CacheData>,
    pub budget_seconds: f64,
    pub optimum: f64,
    /// Equidistant sampling times in (0, budget].
    pub times: Vec<f64>,
    /// Baseline value at each sampling time.
    pub baseline_values: Vec<f64>,
}

impl SpaceEval {
    /// Prepare a space: compute the cutoff budget and baseline curve.
    pub fn new(
        space: Arc<SearchSpace>,
        cache: Arc<CacheData>,
        cutoff: f64,
        points: usize,
    ) -> SpaceEval {
        let mut baseline = Baseline::new(&cache);
        let budget_seconds = baseline.budget_seconds(cutoff);
        let times = sampling_times(budget_seconds, points);
        let baseline_values: Vec<f64> =
            times.iter().map(|&t| baseline.value_at_time(t)).collect();
        SpaceEval {
            label: format!("{}@{}", cache.kernel, cache.device),
            optimum: baseline.optimum,
            space,
            cache,
            budget_seconds,
            times,
            baseline_values,
        }
    }

    /// Score one set of repeated traces on this space (Eq. 2 per point).
    pub fn score_traces(&self, traces: &[Trace]) -> Vec<f64> {
        let mut i = 0usize;
        let fallback = |_t: f64| {
            let v = self.baseline_values[i];
            i += 1;
            v
        };
        let curve = PerformanceCurve::from_traces(traces, &self.times, fallback);
        curve
            .values
            .iter()
            .zip(&self.baseline_values)
            .map(|(&v, &b)| score_at(b, v, self.optimum))
            .collect()
    }
}

/// The outcome of evaluating one (algorithm, hyperparameters) pair.
#[derive(Clone, Debug)]
pub struct AggregateResult {
    /// Eq. (2) score per space per sampling point: `[space][t]`.
    pub per_space_scores: Vec<Vec<f64>>,
    /// Mean over spaces at each (relative) sampling point.
    pub aggregate_curve: Vec<f64>,
    /// Eq. (3): mean of the aggregate curve — the scalar score.
    pub score: f64,
}

impl AggregateResult {
    /// Mean score per space (for the per-space impact figures 4 and 7).
    pub fn per_space_means(&self) -> Vec<f64> {
        self.per_space_scores
            .iter()
            .map(|s| crate::util::stats::mean(s))
            .collect()
    }
}

/// Run `repeats` simulated tuning runs of `algo(hp)` on every space and
/// aggregate the scores (Eq. 3). Runs are parallelized over
/// (space, repeat) pairs; seeds are deterministic per (seed, space,
/// repeat), so results are reproducible regardless of thread scheduling.
pub fn evaluate_algorithm(
    algo: &str,
    hp: &HyperParams,
    spaces: &[SpaceEval],
    repeats: usize,
    seed: u64,
) -> Result<AggregateResult> {
    // Validate the algorithm name once, up front.
    optimizers::create(algo, hp)?;
    let n_jobs = spaces.len() * repeats;
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n_jobs.max(1));

    // Lock-free scatter/gather: every job writes a distinct trace slot, so
    // workers accumulate (job, trace) pairs locally and the slots are
    // filled after join — no shared Mutex on the hot path.
    let mut slots: Vec<Option<Trace>> = Vec::new();
    slots.resize_with(n_jobs, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    // Per-worker optimizer instance (Optimizer is stateless
                    // across runs but create() is cheap anyway).
                    let opt = optimizers::create(algo, hp).expect("validated above");
                    let mut local: Vec<(usize, Trace)> = Vec::new();
                    loop {
                        let job = next.fetch_add(1, Ordering::Relaxed);
                        if job >= n_jobs {
                            break;
                        }
                        let s = job / repeats;
                        let r = job % repeats;
                        let se = &spaces[s];
                        let mut sim = SimulationRunner::new_unchecked(
                            Arc::clone(&se.space),
                            Arc::clone(&se.cache),
                        );
                        // Proposal cap: no real tuning run proposes more than a
                        // few multiples of the space size; this bounds the real
                        // cost of schedule-heavy configs that spin on (cheap)
                        // cache revisits.
                        let budget = Budget::seconds(se.budget_seconds)
                            .with_proposal_cap(4 * se.space.len() + 10_000);
                        let mut tuning = Tuning::new(&mut sim, budget);
                        let mut rng = Rng::new(mix64(seed, mix64(s as u64, r as u64)));
                        opt.run(&mut tuning, &mut rng);
                        local.push((job, tuning.finish()));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (job, trace) in h.join().expect("evaluation worker panicked") {
                slots[job] = Some(trace);
            }
        }
    });

    let mut per_space_scores = Vec::with_capacity(spaces.len());
    for (s, se) in spaces.iter().enumerate() {
        let ts: Vec<Trace> = slots[s * repeats..(s + 1) * repeats]
            .iter_mut()
            .map(|t| t.take().expect("job slot unfilled"))
            .collect();
        per_space_scores.push(se.score_traces(&ts));
    }
    let points = per_space_scores[0].len();
    let aggregate_curve: Vec<f64> = (0..points)
        .map(|t| {
            per_space_scores.iter().map(|s| s[t]).sum::<f64>() / per_space_scores.len() as f64
        })
        .collect();
    let score = crate::util::stats::mean(&aggregate_curve);
    Ok(AggregateResult {
        per_space_scores,
        aggregate_curve,
        score,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::{A100, W7800};
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::LiveRunner;
    use crate::runtime::Engine;
    use std::sync::OnceLock;

    fn spaces() -> &'static Vec<SpaceEval> {
        static SPACES: OnceLock<Vec<SpaceEval>> = OnceLock::new();
        SPACES.get_or_init(|| {
            let engine = Arc::new(Engine::native());
            [&A100, &W7800]
                .iter()
                .map(|dev| {
                    let kernel = kernels::kernel_by_name("synthetic").unwrap();
                    let mut live = LiveRunner::new(
                        kernels::kernel_by_name("synthetic").unwrap(),
                        dev,
                        Arc::clone(&engine),
                        NoiseModel::default(),
                        42,
                    );
                    let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                    SpaceEval::new(kernel.space_arc(), cache, 0.95, 20)
                })
                .collect()
        })
    }

    /// The methodology's calibration property: random search must score
    /// ~0 against the analytic random-search baseline.
    #[test]
    fn random_search_scores_near_zero() {
        let r = evaluate_algorithm(
            "random_search",
            &HyperParams::new(),
            spaces(),
            60,
            7,
        )
        .unwrap();
        assert!(
            r.score.abs() < 0.12,
            "random search should match the baseline, got {}",
            r.score
        );
    }

    /// A real optimizer must beat random search on the aggregate score.
    /// (Absolute scores with *default* hyperparameters are modest — that
    /// is the paper's premise; the hypertuning experiments quantify the
    /// lift. Here we only assert the scoring separates the methods.)
    #[test]
    fn good_optimizer_beats_random() {
        let rs = evaluate_algorithm("random_search", &HyperParams::new(), spaces(), 25, 7)
            .unwrap();
        let pso = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 25, 7).unwrap();
        assert!(
            pso.score > rs.score + 0.05,
            "pso {} vs random {}",
            pso.score,
            rs.score
        );
    }

    /// Deterministic despite parallel scheduling.
    #[test]
    fn deterministic_across_runs() {
        let a = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 10, 3).unwrap();
        let b = evaluate_algorithm("pso", &HyperParams::new(), spaces(), 10, 3).unwrap();
        assert_eq!(a.score, b.score);
        assert_eq!(a.aggregate_curve, b.aggregate_curve);
    }

    #[test]
    fn per_space_shapes() {
        let r = evaluate_algorithm("mls", &HyperParams::new(), spaces(), 5, 1).unwrap();
        assert_eq!(r.per_space_scores.len(), 2);
        assert_eq!(r.per_space_scores[0].len(), 20);
        assert_eq!(r.aggregate_curve.len(), 20);
        assert_eq!(r.per_space_means().len(), 2);
        // Score within plausible bounds.
        assert!(r.score > -1.5 && r.score < 1.0);
    }

    #[test]
    fn unknown_algorithm_rejected() {
        assert!(evaluate_algorithm("nope", &HyperParams::new(), spaces(), 2, 1).is_err());
    }
}
