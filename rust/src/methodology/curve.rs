//! Performance-over-time curves at fixed, equidistant sampling points.

use crate::runner::Trace;

/// The mean best-so-far objective value of repeated runs, sampled at
/// equidistant times over a budget.
#[derive(Clone, Debug)]
pub struct PerformanceCurve {
    /// Sampling times in simulated seconds (equidistant in (0, budget]).
    pub times: Vec<f64>,
    /// Mean best-so-far value at each time; where a repeat has found
    /// nothing valid yet, the provided fallback (the baseline value) is
    /// substituted so the score reads 0, not undefined.
    pub values: Vec<f64>,
    /// Number of repeats aggregated.
    pub repeats: usize,
}

/// Equidistant sampling times in (0, budget].
pub fn sampling_times(budget_seconds: f64, points: usize) -> Vec<f64> {
    (1..=points)
        .map(|i| budget_seconds * i as f64 / points as f64)
        .collect()
}

impl PerformanceCurve {
    /// Build from repeated traces. `fallback(i)` supplies the value to
    /// use when a repeat has no valid result at sampling point `i` — the
    /// baseline value at `times[i]`, so that "found nothing" scores 0.
    ///
    /// The fallback is *index-addressed* so callers hand over a slice of
    /// precomputed baseline values directly; the old time-addressed
    /// closure invited callers to count invocations, silently coupling
    /// them to this function calling it exactly once per point, in order.
    ///
    /// Single pass per trace: `times` must be ascending (sampling_times
    /// produces them so), letting a cursor walk each trace once instead of
    /// rescanning from the start per sampling point.
    pub fn from_traces(
        traces: &[Trace],
        times: &[f64],
        mut fallback: impl FnMut(usize) -> f64,
    ) -> PerformanceCurve {
        assert!(!traces.is_empty());
        debug_assert!(times.windows(2).all(|w| w[0] <= w[1]), "times must ascend");
        let fallbacks: Vec<f64> = (0..times.len()).map(&mut fallback).collect();
        let mut sums = vec![0.0f64; times.len()];
        for trace in traces {
            let mut cursor = 0usize;
            let mut best = f64::INFINITY;
            for (ti, &t) in times.iter().enumerate() {
                while cursor < trace.points.len() && trace.points[cursor].clock <= t {
                    let v = trace.points[cursor].value;
                    if v < best {
                        best = v;
                    }
                    cursor += 1;
                }
                sums[ti] += if best.is_finite() { best } else { fallbacks[ti] };
            }
        }
        let values: Vec<f64> = sums.iter().map(|s| s / traces.len() as f64).collect();
        PerformanceCurve {
            times: times.to_vec(),
            values,
            repeats: traces.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runner::TracePoint;

    fn trace(points: &[(f64, f64)]) -> Trace {
        Trace {
            points: points
                .iter()
                .map(|&(clock, value)| TracePoint {
                    config: 0,
                    value,
                    clock,
                    cached: false,
                })
                .collect(),
            elapsed: points.last().map(|p| p.0).unwrap_or(0.0),
            unique_evals: points.len(),
        }
    }

    #[test]
    fn sampling_times_equidistant() {
        let ts = sampling_times(10.0, 5);
        assert_eq!(ts, vec![2.0, 4.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn mean_of_repeats() {
        let t1 = trace(&[(1.0, 5.0), (3.0, 2.0)]);
        let t2 = trace(&[(1.0, 7.0), (3.0, 4.0)]);
        let c = PerformanceCurve::from_traces(&[t1, t2], &[2.0, 4.0], |_| 100.0);
        assert_eq!(c.values, vec![6.0, 3.0]);
        assert_eq!(c.repeats, 2);
    }

    #[test]
    fn fallback_before_first_result() {
        let t1 = trace(&[(5.0, 1.0)]);
        let c = PerformanceCurve::from_traces(&[t1], &[1.0, 6.0], |_| 42.0);
        assert_eq!(c.values, vec![42.0, 1.0]);
    }

    /// The fallback is addressed by sampling-point index: a trace that
    /// only starts after several points must receive each point's own
    /// fallback value, not a value that depends on invocation order.
    #[test]
    fn fallback_is_index_addressed() {
        let baseline = [10.0, 9.0, 8.0, 7.0];
        let t1 = trace(&[(2.5, 1.0)]);
        let c = PerformanceCurve::from_traces(
            &[t1],
            &[1.0, 2.0, 3.0, 4.0],
            |i| baseline[i],
        );
        assert_eq!(c.values, vec![10.0, 9.0, 1.0, 1.0]);
    }

    #[test]
    fn curve_monotone_with_monotone_traces() {
        let t1 = trace(&[(1.0, 5.0), (2.0, 4.0), (3.0, 3.0)]);
        let c = PerformanceCurve::from_traces(&[t1], &[1.0, 2.0, 3.0], |_| 10.0);
        assert!(c.values.windows(2).all(|w| w[1] <= w[0]));
    }
}
