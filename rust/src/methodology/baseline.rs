//! The calibrated random-search baseline.
//!
//! For a fully known search space the expected best-so-far of random
//! search after `n` draws *without replacement* is exact:
//!
//! ```text
//! P(min ≥ v_(i+1)) = C(M-i, n) / C(M, n)       (q_i, computed by the
//! E[min after n]   = Σ_i v_(i) · (q_(i-1) - q_i)  recurrence q_i = q_(i-1)
//!                                              · (M-i-n+1)/(M-i+1))
//! ```
//!
//! This is the methodology's "calculated baseline": deterministic, no
//! Monte-Carlo error. The time axis uses the space's mean per-evaluation
//! cost; draws of invalid configurations consume time but contribute no
//! value, handled by scaling the draw count by the valid fraction.

use crate::dataset::cache::CacheData;
use crate::dataset::simtable::SimTable;
use std::sync::Arc;

/// The random-search baseline for one search space.
#[derive(Clone, Debug)]
pub struct Baseline {
    /// The cache's memoized statistics block (Arc-shared with every other
    /// reader): `sorted_valid_values`, `optimum`, `mean_eval_cost`,
    /// `valid_fraction` are computed once per cache instead of O(n log n)
    /// per `Baseline::new`.
    table: Arc<SimTable>,
    /// Memoized E[min after n valid draws] per integer n. A per-n memo
    /// (not a dense 1..n table) keeps the whole baseline O(m log m): the
    /// budget binary search touches ~log m distinct n, the sampling
    /// points ~2·points more, each evaluated with one O(m) pass.
    memo: crate::util::hash::FastMap<usize, f64>,
    /// Mean simulated cost of one evaluation (over all configs).
    pub mean_cost: f64,
    /// Fraction of configurations that are valid.
    pub valid_fraction: f64,
    /// The optimum (lowest mean value).
    pub optimum: f64,
    /// The median valid value.
    pub median: f64,
}

impl Baseline {
    /// Build from a brute-forced cache. All distribution statistics come
    /// from the cache's memoized [`SimTable`] (same fold orders as the
    /// former per-call computations, so budgets are bit-identical).
    pub fn new(cache: &CacheData) -> Baseline {
        let table = Arc::clone(cache.sim_table());
        let sorted = &table.sorted_valid_values;
        assert!(!sorted.is_empty(), "space has no valid configurations");
        let optimum = sorted[0];
        let median = crate::util::stats::percentile_sorted(sorted, 50.0);
        Baseline {
            memo: crate::util::hash::FastMap::default(),
            mean_cost: table.mean_eval_cost,
            valid_fraction: table.valid_fraction,
            optimum,
            median,
            table,
        }
    }

    /// E[min after `draws` draws without replacement], one O(m) pass:
    /// `E = Σ_i v_(i) (q_(i-1) - q_i)` with
    /// `q_i = C(m-i, draws)/C(m, draws)` by the recurrence
    /// `q_i = q_(i-1) · (m-i-draws+1)/(m-i+1)`.
    fn expected_single(&mut self, draws: usize) -> f64 {
        let m = self.table.sorted_valid_values.len();
        let draws = draws.clamp(1, m);
        if let Some(&v) = self.memo.get(&draws) {
            return v;
        }
        let mut q_prev = 1.0f64;
        let mut e = 0.0f64;
        for i in 1..=m {
            let numer = (m as f64) - (i as f64) - (draws as f64) + 1.0;
            let denom = (m as f64) - (i as f64) + 1.0;
            let q = if numer <= 0.0 { 0.0 } else { q_prev * numer / denom };
            e += self.table.sorted_valid_values[i - 1] * (q_prev - q);
            q_prev = q;
            if q == 0.0 {
                break;
            }
        }
        self.memo.insert(draws, e);
        e
    }

    /// Batch counterpart of [`Baseline::expected_single`]: one pass over
    /// the sorted valid values serves every requested draw count at once,
    /// carrying one `q` accumulator per distinct count. Each count's
    /// arithmetic is the exact scalar recurrence (same multiply/divide
    /// sequence, same early termination at `q == 0`), so the memoized
    /// results are bit-identical to per-count passes.
    fn expected_many(&mut self, draws: &[usize]) {
        struct Acc {
            draws: usize,
            q_prev: f64,
            e: f64,
            live: bool,
        }
        let m = self.table.sorted_valid_values.len();
        let mut missing: Vec<usize> = draws
            .iter()
            .map(|&d| d.clamp(1, m))
            .filter(|d| !self.memo.contains_key(d))
            .collect();
        missing.sort_unstable();
        missing.dedup();
        if missing.is_empty() {
            return;
        }
        let mut acc: Vec<Acc> = missing
            .iter()
            .map(|&d| Acc {
                draws: d,
                q_prev: 1.0,
                e: 0.0,
                live: true,
            })
            .collect();
        let mut live = acc.len();
        for i in 1..=m {
            if live == 0 {
                break;
            }
            let v = self.table.sorted_valid_values[i - 1];
            for a in acc.iter_mut() {
                if !a.live {
                    continue;
                }
                let numer = (m as f64) - (i as f64) - (a.draws as f64) + 1.0;
                let denom = (m as f64) - (i as f64) + 1.0;
                let q = if numer <= 0.0 {
                    0.0
                } else {
                    a.q_prev * numer / denom
                };
                a.e += v * (a.q_prev - q);
                a.q_prev = q;
                if q == 0.0 {
                    a.live = false;
                    live -= 1;
                }
            }
        }
        for a in acc {
            self.memo.insert(a.draws, a.e);
        }
    }

    /// Batch counterpart of [`Baseline::value_at_time`]: warms the per-n
    /// memo for every integer draw count the requested times touch with
    /// one [`Baseline::expected_many`] pass, then maps each time through
    /// the scalar path — all memo hits, so one multi-accumulator walk
    /// over the value distribution serves the whole sampling grid.
    pub fn values_at_times(&mut self, times: &[f64]) -> Vec<f64> {
        let m = self.table.sorted_valid_values.len();
        let mut wanted: Vec<usize> = Vec::with_capacity(times.len() * 2);
        for &t in times {
            // Mirror value_at_time → expected_best's draw derivation.
            let n_valid = ((t / self.mean_cost) * self.valid_fraction).max(1.0);
            if n_valid <= 1.0 {
                wanted.push(1);
            } else {
                wanted.push((n_valid.floor() as usize).min(m));
                wanted.push((n_valid.ceil() as usize).min(m));
            }
        }
        self.expected_many(&wanted);
        times.iter().map(|&t| self.value_at_time(t)).collect()
    }

    /// Expected best after `n_valid` valid draws (interpolated for
    /// fractional n).
    pub fn expected_best(&mut self, n_valid: f64) -> f64 {
        let m = self.table.sorted_valid_values.len();
        if n_valid <= 1.0 {
            return self.expected_single(1);
        }
        let lo = (n_valid.floor() as usize).min(m);
        let hi = (n_valid.ceil() as usize).min(m);
        let e_lo = self.expected_single(lo);
        let e_hi = self.expected_single(hi);
        let frac = (n_valid - lo as f64).clamp(0.0, 1.0);
        e_lo + (e_hi - e_lo) * frac
    }

    /// Baseline value at simulated time `t` seconds: draws = t/cost scaled
    /// by the valid fraction.
    pub fn value_at_time(&mut self, t: f64) -> f64 {
        let draws = (t / self.mean_cost) * self.valid_fraction;
        self.expected_best(draws.max(1.0))
    }

    /// The budget: the time at which the baseline reaches
    /// `median - cutoff*(median - optimum)`, capped at draws = |space|.
    pub fn budget_seconds(&mut self, cutoff: f64) -> f64 {
        let target = self.median - cutoff * (self.median - self.optimum);
        let m = self.table.sorted_valid_values.len();
        // Binary search over valid draw count (expected_best is monotone
        // non-increasing in n).
        let mut lo = 1usize;
        let mut hi = m;
        if self.expected_best(m as f64) > target {
            // Cutoff not reachable (cutoff=1 with ties); use the full space.
            return m as f64 / self.valid_fraction * self.mean_cost;
        }
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.expected_best(mid as f64) <= target {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        (lo as f64) / self.valid_fraction * self.mean_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::cache::{CacheData, ConfigRecord};

    fn cache_with_values(values: &[f64]) -> CacheData {
        CacheData::new(
            "t",
            "d",
            "",
            0,
            1,
            0.0,
            vec!["x".into()],
            values
                .iter()
                .enumerate()
                .map(|(i, &v)| ConfigRecord {
                    key: i.to_string(),
                    value: v,
                    observations: vec![v],
                    compile_time: 1.0,
                    valid: v.is_finite(),
                })
                .collect(),
        )
    }

    #[test]
    fn expected_best_matches_bruteforce_enumeration() {
        // M=4, values 1..4: E[min after 2 draws] over all C(4,2)=6 pairs:
        // mins = 1,1,1,2,2,3 -> 10/6.
        let mut b = Baseline::new(&cache_with_values(&[4.0, 2.0, 3.0, 1.0]));
        assert!((b.expected_best(2.0) - 10.0 / 6.0).abs() < 1e-12);
        // n = M -> the optimum with certainty.
        assert!((b.expected_best(4.0) - 1.0).abs() < 1e-12);
        // n = 1 -> the mean.
        assert!((b.expected_best(1.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn expected_best_monotone_nonincreasing() {
        let vals: Vec<f64> = (1..200).map(|i| (i as f64).sqrt()).collect();
        let mut b = Baseline::new(&cache_with_values(&vals));
        let mut prev = f64::INFINITY;
        for n in 1..=199 {
            let e = b.expected_best(n as f64);
            assert!(e <= prev + 1e-12, "n={n}");
            prev = e;
        }
    }

    #[test]
    fn interpolation_between_integer_draws() {
        let mut b = Baseline::new(&cache_with_values(&[1.0, 2.0, 3.0, 4.0]));
        let e2 = b.expected_best(2.0);
        let e3 = b.expected_best(3.0);
        let e25 = b.expected_best(2.5);
        assert!((e25 - (e2 + e3) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn invalid_configs_slow_the_baseline() {
        let valid = cache_with_values(&[1.0, 2.0, 3.0, 4.0]);
        let inf = f64::INFINITY;
        let half = cache_with_values(&[1.0, 2.0, 3.0, 4.0, inf, inf, inf, inf]);
        let mut bv = Baseline::new(&valid);
        let mut bh = Baseline::new(&half);
        // At the same time budget, the half-invalid space has fewer valid draws.
        let t = 10.0 * bv.mean_cost;
        assert!(bh.value_at_time(t) >= bv.value_at_time(t) - 1e-12);
        assert!(bh.valid_fraction < bv.valid_fraction);
    }

    #[test]
    fn expected_many_matches_single_bitwise() {
        let vals: Vec<f64> = (1..150).map(|i| ((i as f64) * 0.37).sin() + 2.0).collect();
        let mut a = Baseline::new(&cache_with_values(&vals));
        let mut b = Baseline::new(&cache_with_values(&vals));
        // Duplicates and out-of-range counts must be handled (clamped)
        // exactly as the scalar path clamps them.
        let draws = [1usize, 2, 3, 7, 20, 20, 149, 200, 0];
        b.expected_many(&draws);
        for &d in &draws {
            assert_eq!(
                a.expected_single(d).to_bits(),
                b.expected_single(d).to_bits(),
                "draws={d}"
            );
        }
    }

    #[test]
    fn values_at_times_matches_scalar_bitwise() {
        let inf = f64::INFINITY;
        let vals: Vec<f64> = (1..120)
            .map(|i| if i % 5 == 0 { inf } else { (i as f64).sqrt() })
            .collect();
        let mut a = Baseline::new(&cache_with_values(&vals));
        let mut b = Baseline::new(&cache_with_values(&vals));
        let times: Vec<f64> = (0..40).map(|i| 0.3 + i as f64 * 2.1).collect();
        let batch = b.values_at_times(&times);
        for (k, &t) in times.iter().enumerate() {
            assert_eq!(a.value_at_time(t).to_bits(), batch[k].to_bits(), "t={t}");
        }
    }

    #[test]
    fn budget_reaches_cutoff() {
        let vals: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let mut b = Baseline::new(&cache_with_values(&vals));
        let budget = b.budget_seconds(0.95);
        assert!(budget > 0.0);
        let v = b.value_at_time(budget);
        let target = b.median - 0.95 * (b.median - b.optimum);
        assert!(v <= target * 1.01, "v={v} target={target}");
        // Stricter cutoff costs more time.
        let b99 = b.budget_seconds(0.99);
        assert!(b99 > budget);
    }
}
