//! The performance score of Eq. (2).

use super::baseline::Baseline;
use super::curve::PerformanceCurve;

/// Eq. (2) at one sampling point: `(S_baseline(t) - F_t) / (S_baseline(t)
/// - S_opt)`. 0 = baseline parity, 1 = optimum; negative = worse than the
/// baseline.
pub fn score_at(baseline_value: f64, algorithm_value: f64, optimum: f64) -> f64 {
    let denom = baseline_value - optimum;
    if denom <= 0.0 {
        // Baseline already at the optimum: any parity scores 1.
        return if algorithm_value <= optimum { 1.0 } else { 0.0 };
    }
    (baseline_value - algorithm_value) / denom
}

/// Score curve for one search space: Eq. (2) applied at every sampling
/// point of a performance curve.
pub fn score_curve(baseline: &mut Baseline, curve: &PerformanceCurve) -> Vec<f64> {
    // One batched baseline pass over the whole sampling grid
    // (bit-identical to per-point value_at_time calls).
    let baseline_values = baseline.values_at_times(&curve.times);
    baseline_values
        .iter()
        .zip(&curve.values)
        .map(|(&b, &v)| score_at(b, v, baseline.optimum))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_anchors() {
        // At the baseline -> 0; at the optimum -> 1.
        assert_eq!(score_at(10.0, 10.0, 2.0), 0.0);
        assert_eq!(score_at(10.0, 2.0, 2.0), 1.0);
        // Halfway -> 0.5; worse than baseline -> negative.
        assert!((score_at(10.0, 6.0, 2.0) - 0.5).abs() < 1e-12);
        assert!(score_at(10.0, 14.0, 2.0) < 0.0);
    }

    #[test]
    fn degenerate_baseline() {
        assert_eq!(score_at(2.0, 2.0, 2.0), 1.0);
        assert_eq!(score_at(2.0, 3.0, 2.0), 0.0);
    }
}
