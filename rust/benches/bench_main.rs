//! Benchmark harness (`cargo bench`). Criterion is unavailable offline, so
//! this is a small self-contained harness: adaptive iteration count,
//! warmup, mean ± stddev, and a throughput column where meaningful.
//!
//! Groups:
//!   space      — search-space enumeration per kernel (constraint engine)
//!   engine     — batched device-model evaluation, PJRT vs native (L1/L2)
//!   sim        — simulation-mode replay rate (the paper's feasibility core)
//!   baseline   — methodology baseline/budget computation per space
//!   optimizer  — optimizer stepping rate in simulation mode
//!   bruteforce — full-space brute-force (Table II regeneration cost)
//!   hypertune  — one exhaustive campaign + meta-level scoring (Tables III/IV,
//!                Figs 2-9 building block)
//!
//! Filter with `cargo bench -- <substring>`.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tunetuner::dataset::{bruteforce, hub::Hub};
use tunetuner::gpu::specs::{all_devices, A100};
use tunetuner::hypertuning;
use tunetuner::kernels;
use tunetuner::methodology::{evaluate_algorithm, SpaceEval};
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::perfmodel::NoiseModel;
use tunetuner::runner::{Budget, LiveRunner, SimulationRunner, Tuning};
use tunetuner::runtime::Engine;
use tunetuner::util::rng::Rng;

struct Bench {
    filter: Option<String>,
}

impl Bench {
    /// Time `f` adaptively: enough iterations to pass ~0.4s, after warmup.
    fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Option<Duration> {
        if let Some(filter) = &self.filter {
            if !name.contains(filter.as_str()) {
                return None;
            }
        }
        // Warmup + calibration.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = (Duration::from_millis(400).as_nanos() / once.as_nanos()).clamp(1, 10_000)
            as usize;
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / samples.len() as f64;
        println!(
            "{name:<46} {:>12}  ±{:>5.1}%  ({} iters)",
            fmt_time(mean),
            (var.sqrt() / mean * 100.0).min(999.0),
            samples.len()
        );
        Some(Duration::from_secs_f64(mean))
    }

    fn throughput(&self, name: &str, items: usize, mut f: impl FnMut()) {
        if let Some(d) = self.run(name, &mut f) {
            println!(
                "{:<46} {:>12.0} items/s",
                format!("  -> {name}"),
                items as f64 / d.as_secs_f64()
            );
        }
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} us", secs * 1e6)
    }
}

fn main() {
    // `cargo bench -- <filter>` (skip the --bench flag cargo passes).
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .map(|s| s.to_string());
    let b = Bench { filter };
    println!("{:-^78}", " tunetuner benchmarks ");

    // ---- space: enumeration ---------------------------------------------------
    for name in ["synthetic", "hotspot", "dedispersion", "convolution", "gemm"] {
        b.run(&format!("space/build/{name}"), || {
            kernels::kernel_by_name(name).unwrap().space().len()
        });
    }

    // ---- engine: batched device-model evaluation --------------------------------
    let kernel = kernels::kernel_by_name("gemm").unwrap();
    let feats = kernel.all_features();
    let dvec = A100.to_vector();
    let native = Engine::native();
    b.throughput(&format!("engine/native/batch{}", feats.len()), feats.len(), || {
        native.measure(&feats, &dvec).unwrap();
    });
    match Engine::pjrt(&Engine::default_artifacts_dir()) {
        Ok(pjrt) => {
            b.throughput(&format!("engine/pjrt/batch{}", feats.len()), feats.len(), || {
                pjrt.measure(&feats, &dvec).unwrap();
            });
            let small = &feats[..256];
            b.throughput("engine/pjrt/batch256", 256, || {
                pjrt.measure(small, &dvec).unwrap();
            });
        }
        Err(e) => println!("engine/pjrt SKIPPED ({e})"),
    }

    // ---- bruteforce --------------------------------------------------------------
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    b.run("bruteforce/gemm@A100(6728cfg x 32obs)", || {
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("gemm").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        bruteforce::bruteforce(&mut live).unwrap().records.len()
    });

    // ---- shared hub-backed setup for sim/optimizer/hypertune benches --------------
    let hub = Hub::new(Hub::default_root());
    if !hub.exists("gemm", "A100") {
        println!("(hub missing: run `tunetuner bruteforce` first for sim benches)");
        println!("{:-^78}", " done ");
        return;
    }
    let cache = hub.load("gemm", "A100").unwrap();
    let space = kernel.space_arc();

    // ---- sim: replay rate -----------------------------------------------------------
    let n = space.len();
    b.throughput("sim/replay/sequential-10k", 10_000, || {
        let mut sim = SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
        let mut tuning = Tuning::new(&mut sim, Budget::evals(usize::MAX));
        for i in 0..10_000usize {
            tuning.eval(i % n);
        }
    });

    // ---- baseline ------------------------------------------------------------------
    b.run("baseline/SpaceEval::new/gemm@A100", || {
        SpaceEval::new(Arc::clone(&space), Arc::clone(&cache), 0.95, 50).budget_seconds
    });

    // ---- optimizer stepping rate ------------------------------------------------------
    for algo in optimizers::optimizer_names() {
        b.throughput(&format!("optimizer/{algo}/500-evals"), 500, || {
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            let mut tuning = Tuning::new(&mut sim, Budget::evals(500));
            let opt = optimizers::create(algo, &HyperParams::new()).unwrap();
            opt.run(&mut tuning, &mut Rng::new(3));
        });
    }

    // ---- hypertune building blocks ------------------------------------------------------
    let devices: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
    if devices.iter().all(|d| hub.exists("gemm", d)) {
        let evals: Vec<SpaceEval> = devices
            .iter()
            .map(|d| SpaceEval::new(Arc::clone(&space), hub.load("gemm", d).unwrap(), 0.95, 30))
            .collect();
        b.run("hypertune/evaluate_algorithm(ga,6sp,5rep)", || {
            evaluate_algorithm("genetic_algorithm", &HyperParams::new(), &evals, 5, 7)
                .unwrap()
                .score
        });
        b.run("hypertune/exhaustive(da,8cfg,6sp,3rep)", || {
            let hp_space = hypertuning::limited_space("dual_annealing").unwrap();
            hypertuning::exhaustive_tuning("dual_annealing", &hp_space, "limited", &evals, 3, 1)
                .unwrap()
                .best()
                .score
        });
    }
    println!("{:-^78}", " done ");
}
