//! Benchmark harness (`cargo bench`). Criterion is unavailable offline, so
//! this is a small self-contained harness: adaptive iteration count,
//! warmup, mean ± stddev, and a throughput column where meaningful.
//!
//! Groups:
//!   space      — search-space enumeration per kernel (constraint engine)
//!   engine     — batched device-model evaluation, PJRT vs native (L1/L2)
//!   sim        — simulation-mode replay rate (the paper's feasibility core):
//!                eval_lite lookup throughput, eval_batch gather + SimTable build
//!   cache      — on-disk cache load: gzipped JSON vs the T4B binary sidecar
//!   tuning     — per-run buffer pooling (scratch_reuse vs fresh_alloc) and
//!                batched proposals (batch_vs_scalar: gather vs eval loop)
//!   methodology— batched campaign scoring (score_campaign over all traces)
//!   baseline   — methodology baseline/budget computation per space
//!   optimizer  — optimizer stepping rate in simulation mode
//!   bruteforce — full-space brute-force (Table II regeneration cost)
//!   executor   — persistent pool vs spawn-per-call + campaign rate
//!   sweep      — full-registry hypertune sweep smoke (every grid-bearing
//!                optimizer, tiny budget, synthetic kernel)
//!   metasweep  — meta-strategy race smoke (all registered strategies vs a
//!                prebuilt exhaustive reference, synthetic kernel)
//!   hypertune  — one exhaustive campaign + meta-level scoring (Tables III/IV,
//!                Figs 2-9 building block)
//!
//! Filter with `cargo bench -- <substring>`; comma separates alternatives
//! (`cargo bench -- sim/,cache/,tuning/` runs the three replay groups).
//!
//! Results are also written as machine-readable JSON (group → mean seconds,
//! items/s) to `BENCH.json` (override with `BENCH_JSON=<path>`), so the
//! perf trajectory can be tracked across PRs (`BENCH_*.json`). Set
//! `TUNETUNER_BENCH_SMOKE=1` for a fast smoke pass (CI): fewer iterations,
//! same coverage.

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use std::sync::Arc;
use std::time::{Duration, Instant};
use tunetuner::dataset::{bruteforce, cache::CacheData, hub::Hub, simtable::SimTable, t4b};
use tunetuner::gpu::specs::{all_devices, A100};
use tunetuner::hypertuning;
use tunetuner::kernels;
use tunetuner::methodology::{evaluate_algorithm, SpaceEval};
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::perfmodel::NoiseModel;
use tunetuner::runner::{
    Budget, LiveRunner, Runner, SimulationRunner, Trace, TracePoint, Tuning, TuningScratch,
};
use tunetuner::runtime::Engine;
use tunetuner::util::json::Json;
use tunetuner::util::rng::Rng;

/// One finished measurement, kept for the BENCH.json report.
struct Record {
    name: String,
    mean_s: f64,
    stddev_frac: f64,
    iters: usize,
    items_per_s: Option<f64>,
}

struct Bench {
    filter: Option<String>,
    /// Smoke mode: much shorter sampling window (CI gate).
    smoke: bool,
    records: Vec<Record>,
}

impl Bench {
    /// Time `f` adaptively: enough iterations to fill the sampling window
    /// (~0.4s, ~40ms in smoke mode), after warmup.
    fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> Option<Duration> {
        if let Some(filter) = &self.filter {
            if !filter
                .split(',')
                .any(|alt| !alt.is_empty() && name.contains(alt))
            {
                return None;
            }
        }
        let (window, max_iters) = if self.smoke {
            (Duration::from_millis(40), 50)
        } else {
            (Duration::from_millis(400), 10_000)
        };
        // Warmup + calibration.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = (window.as_nanos() / once.as_nanos()).clamp(1, max_iters) as usize;
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples
            .iter()
            .map(|s| (s - mean) * (s - mean))
            .sum::<f64>()
            / samples.len() as f64;
        let stddev_frac = (var.sqrt() / mean).min(9.99);
        println!(
            "{name:<46} {:>12}  ±{:>5.1}%  ({} iters)",
            fmt_time(mean),
            stddev_frac * 100.0,
            samples.len()
        );
        self.records.push(Record {
            name: name.to_string(),
            mean_s: mean,
            stddev_frac,
            iters: samples.len(),
            items_per_s: None,
        });
        Some(Duration::from_secs_f64(mean))
    }

    fn throughput(&mut self, name: &str, items: usize, mut f: impl FnMut()) {
        if let Some(d) = self.run(name, &mut f) {
            let rate = items as f64 / d.as_secs_f64();
            println!("{:<46} {:>12.0} items/s", format!("  -> {name}"), rate);
            if let Some(last) = self.records.last_mut() {
                last.items_per_s = Some(rate);
            }
        }
    }

    /// Write the machine-readable report (group → mean seconds, items/s).
    fn write_report(&self) {
        let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH.json".to_string());
        let benches: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("name", r.name.as_str().into())
                    .set(
                        "group",
                        r.name.split('/').next().unwrap_or(&r.name).into(),
                    )
                    .set("mean_s", r.mean_s.into())
                    .set("stddev_frac", r.stddev_frac.into())
                    .set("iters", r.iters.into());
                match r.items_per_s {
                    Some(rate) => o.set("items_per_s", rate.into()),
                    None => o.set("items_per_s", Json::Null),
                };
                o
            })
            .collect();
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        let mut j = Json::obj();
        j.set("schema", "tunetuner-bench".into())
            .set("schema_version", 1usize.into())
            .set("smoke", self.smoke.into())
            // Filter used for this run (null = full suite), so partial
            // snapshots are distinguishable in the BENCH_*.json trajectory.
            .set(
                "filter",
                match &self.filter {
                    Some(f) => f.as_str().into(),
                    None => Json::Null,
                },
            )
            .set("generated_unix", unix.into())
            .set("benches", Json::Arr(benches));
        let out = std::path::Path::new(&path);
        match tunetuner::util::fsio::atomic_write(out, j.to_pretty().as_bytes()) {
            Ok(()) => println!("(wrote {} results to {path})", self.records.len()),
            Err(e) => eprintln!("(failed to write {path}: {e})"),
        }
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} us", secs * 1e6)
    }
}

fn main() {
    // `cargo bench -- <filter>` (skip the --bench flag cargo passes).
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .map(|s| s.to_string());
    let smoke = std::env::var("TUNETUNER_BENCH_SMOKE")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    let mut b = Bench {
        filter,
        smoke,
        records: Vec::new(),
    };
    println!("{:-^78}", " tunetuner benchmarks ");

    // ---- space: enumeration ---------------------------------------------------
    for name in ["synthetic", "hotspot", "dedispersion", "convolution", "gemm"] {
        b.run(&format!("space/build/{name}"), || {
            kernels::kernel_by_name(name).unwrap().space().len()
        });
    }

    // ---- space: packed-rank hot queries ----------------------------------------
    {
        let space = kernels::kernel_by_name("gemm").unwrap().space_arc();
        let n = space.len();
        b.throughput("space/index_of/gemm-10k", 10_000, || {
            let mut acc = 0usize;
            for i in 0..10_000usize {
                let idx = (i * 2654435761) % n;
                acc += space.index_of(space.encoded(idx)).unwrap();
            }
            std::hint::black_box(acc);
        });
        b.throughput("space/random_neighbor/gemm-10k", 10_000, || {
            let mut rng = Rng::new(7);
            let mut cur = 0usize;
            for _ in 0..10_000usize {
                cur = space.random_neighbor(
                    cur,
                    tunetuner::searchspace::Neighborhood::Hamming,
                    &mut rng,
                );
            }
            std::hint::black_box(cur);
        });
        b.throughput("space/neighbors_csr/gemm-10k", 10_000, || {
            // CSR slice borrows (graph built once, amortized): the path the
            // shared local-search engine walks every descent pass.
            let hood = tunetuner::searchspace::Neighborhood::Adjacent;
            let mut acc = 0usize;
            let mut cur = 0usize;
            for _ in 0..10_000usize {
                let ns = space.neighbors(cur, hood);
                acc += ns.len();
                cur = ns.first().map(|&x| x as usize).unwrap_or((cur + 1) % n);
            }
            std::hint::black_box(acc);
        });
        b.throughput("space/neighbors_probe/gemm-10k", 10_000, || {
            // Probing visitor on the same walk, for the before/after delta.
            let hood = tunetuner::searchspace::Neighborhood::Adjacent;
            let mut buf = Vec::new();
            let mut acc = 0usize;
            let mut cur = 0usize;
            for _ in 0..10_000usize {
                space.neighbors_into(cur, hood, &mut buf);
                acc += buf.len();
                cur = buf.first().copied().unwrap_or((cur + 1) % n);
            }
            std::hint::black_box(acc);
        });
        b.throughput("space/snap/gemm-10k", 10_000, || {
            let mut rng = Rng::new(9);
            let dims = space.dims().to_vec();
            let mut target: Vec<f64> = dims.iter().map(|&d| d as f64 / 2.0).collect();
            let mut acc = 0usize;
            for i in 0..10_000usize {
                let d = i % dims.len();
                target[d] = (i % dims[d].max(1)) as f64 + 0.4;
                acc += space.snap(&target, &mut rng);
            }
            std::hint::black_box(acc);
        });
    }

    // ---- space: constrained compressed-rank spaces (PR 7) ----------------------
    // Synthetic spacegen spaces past the kernel scale: 128x128x64 = 2^20
    // Cartesian ranks at ~1% validity, served by the compressed sampled-
    // select index with the flat buffer elided, benched head-to-head
    // against the bitset index on the *same* space (the PR 7 acceptance
    // gate: compressed index_of within 2x of the bitset path). The full
    // (non-smoke) pass adds a 512^3 = 1.3e8-rank hash-family space — the
    // regime the bitset cannot represent at all.
    let constrained_names = "space/constrained_build/mixed-1M \
         space/constrained_index_of/compressed-10k space/constrained_index_of/bitset-10k \
         space/constrained_neighbors/compressed-10k space/constrained_snap/compressed-10k \
         space/constrained_build/hash-134M space/constrained_index_of/compressed-134M-10k";
    let wants_constrained = b
        .filter
        .as_ref()
        .map(|f| {
            f.split(',')
                .any(|alt| !alt.is_empty() && constrained_names.contains(alt))
        })
        .unwrap_or(true);
    if wants_constrained {
        use tunetuner::searchspace::{
            BuildOptions, ConstraintFamily, FlatPolicy, IndexKind, Neighborhood, SpaceGenSpec,
        };
        let spec = SpaceGenSpec::new(vec![128, 128, 64], 0.01, ConstraintFamily::Mixed, 7);
        b.run("space/constrained_build/mixed-1M", || {
            spec.build().unwrap().len()
        });
        let compressed = spec
            .build_with(BuildOptions {
                index: IndexKind::Compressed,
                flat: FlatPolicy::Elide,
            })
            .unwrap();
        let bitset = spec
            .build_with(BuildOptions {
                index: IndexKind::Bitset,
                flat: FlatPolicy::Materialize,
            })
            .unwrap();
        for (name, sp) in [
            ("space/constrained_index_of/compressed-10k", &compressed),
            ("space/constrained_index_of/bitset-10k", &bitset),
        ] {
            let n = sp.len();
            b.throughput(name, 10_000, || {
                let mut acc = 0usize;
                for i in 0..10_000usize {
                    let idx = (i * 2654435761) % n;
                    acc += sp.index_of_rank(sp.rank_of(idx)).unwrap();
                }
                std::hint::black_box(acc);
            });
        }
        {
            let n = compressed.len();
            b.throughput("space/constrained_neighbors/compressed-10k", 10_000, || {
                let mut rng = Rng::new(7);
                let mut cur = 0usize;
                for _ in 0..10_000usize {
                    cur = compressed.random_neighbor(cur, Neighborhood::Hamming, &mut rng);
                }
                std::hint::black_box(cur % n);
            });
            b.throughput("space/constrained_snap/compressed-10k", 10_000, || {
                let mut rng = Rng::new(9);
                let dims = compressed.dims().to_vec();
                let mut target: Vec<f64> = dims.iter().map(|&d| d as f64 / 2.0).collect();
                let mut acc = 0usize;
                for i in 0..10_000usize {
                    let d = i % dims.len();
                    target[d] = (i % dims[d].max(1)) as f64 + 0.4;
                    acc += compressed.snap(&target, &mut rng);
                }
                std::hint::black_box(acc);
            });
        }
        if !b.smoke {
            // 512^3 = 134M Cartesian ranks at ~1% validity: enumeration
            // bandwidth + compressed lookups where no bitset fits.
            let big_spec =
                SpaceGenSpec::new(vec![512, 512, 512], 0.01, ConstraintFamily::Hash, 7);
            b.run("space/constrained_build/hash-134M", || {
                big_spec.build().unwrap().len()
            });
            let big = big_spec.build().unwrap();
            let n = big.len();
            b.throughput("space/constrained_index_of/compressed-134M-10k", 10_000, || {
                let mut acc = 0usize;
                for i in 0..10_000usize {
                    let idx = (i * 2654435761) % n;
                    acc += big.index_of_rank(big.rank_of(idx)).unwrap();
                }
                std::hint::black_box(acc);
            });
        }
    }

    // ---- engine: batched device-model evaluation --------------------------------
    let kernel = kernels::kernel_by_name("gemm").unwrap();
    let feats = kernel.all_features();
    let dvec = A100.to_vector();
    let native = Engine::native();
    b.throughput(&format!("engine/native/batch{}", feats.len()), feats.len(), || {
        native.measure(&feats, &dvec).unwrap();
    });
    match Engine::pjrt(&Engine::default_artifacts_dir()) {
        Ok(pjrt) => {
            b.throughput(&format!("engine/pjrt/batch{}", feats.len()), feats.len(), || {
                pjrt.measure(&feats, &dvec).unwrap();
            });
            let small = &feats[..256];
            b.throughput("engine/pjrt/batch256", 256, || {
                pjrt.measure(small, &dvec).unwrap();
            });
        }
        Err(e) => println!("engine/pjrt SKIPPED ({e})"),
    }

    // ---- bruteforce --------------------------------------------------------------
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    b.run("bruteforce/gemm@A100(6728cfg x 32obs)", || {
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("gemm").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        bruteforce::bruteforce(&mut live).unwrap().records.len()
    });

    // ---- executor: persistent pool vs spawn-per-call (reuse_vs_spawn) -------------
    // The meta-tuning path runs ~150 evaluate_algorithm calls back to back;
    // before the campaign API each call spawned a fresh thread::scope. This
    // group measures exactly that delta on a synthetic-kernel workload that
    // needs no hub: N batches of 2 tuning runs each, scatter/gathered either
    // through the persistent executor or through per-call scoped threads.
    //
    // The setup (synthetic brute-force) is gated on the filter so e.g.
    // `cargo bench -- space` doesn't pay for it.
    let executor_bench_names = "executor/spawn_scope/100-runs \
         executor/persistent/100-runs executor/campaign_run/4-repeats";
    let wants_executor = b
        .filter
        .as_ref()
        .map(|f| {
            f.split(',')
                .any(|alt| !alt.is_empty() && executor_bench_names.contains(alt))
        })
        .unwrap_or(true);
    if wants_executor {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        let syn_cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
        let syn_space = kernel.space_arc();
        let run_one = {
            let space = Arc::clone(&syn_space);
            let cache = Arc::clone(&syn_cache);
            move |seed: u64| {
                let mut sim =
                    SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
                let mut tuning = Tuning::new(&mut sim, Budget::evals(20));
                let opt = optimizers::create("random_search", &HyperParams::new()).unwrap();
                opt.run(&mut tuning, &mut Rng::new(seed));
                tuning.finish().unique_evals
            }
        };
        let batches = 50usize;
        {
            let run_one = run_one.clone();
            b.throughput("executor/spawn_scope/100-runs", batches * 2, move || {
                let mut acc = 0usize;
                for batch in 0..batches {
                    let mut out = [0usize; 2];
                    std::thread::scope(|scope| {
                        for (r, slot) in out.iter_mut().enumerate() {
                            let run_one = &run_one;
                            scope.spawn(move || {
                                *slot = run_one((batch * 2 + r) as u64);
                            });
                        }
                    });
                    acc += out[0] + out[1];
                }
                std::hint::black_box(acc);
            });
        }
        {
            let pool = tunetuner::campaign::Executor::global();
            b.throughput("executor/persistent/100-runs", batches * 2, move || {
                let mut acc = 0usize;
                for batch in 0..batches {
                    let run_one = run_one.clone();
                    let out =
                        pool.scatter(2, move |r| run_one((batch * 2 + r) as u64));
                    acc += out[0] + out[1];
                }
                std::hint::black_box(acc);
            });
        }
        // End-to-end campaign rate on the same workload (scoring included).
        let campaign = tunetuner::campaign::Campaign::new("random_search")
            .space_evals(vec![SpaceEval::new(
                Arc::clone(&syn_space),
                Arc::clone(&syn_cache),
                0.95,
                20,
            )])
            .repeats(4)
            .budget(tunetuner::campaign::BudgetPolicy::Evals(20));
        b.throughput("executor/campaign_run/4-repeats", 4, || {
            std::hint::black_box(campaign.run().unwrap().score());
        });
    }

    // ---- sim replay stack: SimTable lookups, cache formats, scratch pooling -------
    // The simulator's throughput is the denominator of every meta-sweep.
    // sim/eval_lite is the raw columnar-lookup rate (the PR-4 acceptance
    // gate: >= 10x the record-walk rate it replaced), sim/eval_batch the
    // batched gather over the same table (the PR-6 acceptance gate: >= 2x
    // the per-call loop), sim/table_build the one-time cost it amortizes,
    // cache/load_* the JSON-vs-T4B startup delta, tuning/* the
    // pooled-scratch and batched-proposal deltas, and methodology/* the
    // one-pass campaign scoring. Runs on the synthetic kernel (no hub
    // needed); setup is filter-gated.
    let sim_bench_names = "sim/eval_lite/10k sim/eval_batch/10k sim/table_build/synthetic \
         cache/load_json/synthetic cache/load_t4b/synthetic \
         tuning/scratch_reuse/20x50-evals tuning/fresh_alloc/20x50-evals \
         tuning/batch_vs_scalar/gather tuning/batch_vs_scalar/scalar \
         methodology/score_batch/2sp-25rep";
    let wants_sim = b
        .filter
        .as_ref()
        .map(|f| {
            f.split(',')
                .any(|alt| !alt.is_empty() && sim_bench_names.contains(alt))
        })
        .unwrap_or(true);
    if wants_sim {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        let space = kernel.space_arc();
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
        let n = space.len();

        {
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            b.throughput("sim/eval_lite/10k", 10_000, move || {
                let mut acc = 0.0f64;
                for i in 0..10_000usize {
                    let (value, cost) = sim.evaluate_lite(i % n);
                    acc += value.min(1e9) + cost;
                }
                std::hint::black_box(acc);
            });
        }
        {
            // Same 10k lookups served as one gather + one accounting
            // commit — what a population optimizer's generation pays.
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            let idxs: Vec<usize> = (0..10_000usize).map(|i| i % n).collect();
            let mut out: Vec<(f64, f64)> = Vec::new();
            b.throughput("sim/eval_batch/10k", 10_000, move || {
                sim.evaluate_batch_lite(&idxs, &mut out);
                sim.batch_committed(&out);
                let mut acc = 0.0f64;
                for &(value, cost) in &out {
                    acc += value.min(1e9) + cost;
                }
                std::hint::black_box(acc);
            });
        }
        b.run("sim/table_build/synthetic", || {
            // Direct build, bypassing the per-cache memo.
            SimTable::build(&cache).n_valid
        });

        let dir = std::env::temp_dir().join(format!("tt_bench_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).ok();
        let json_path = dir.join("synthetic.json.gz");
        cache.save(&json_path).unwrap();
        let t4b_path = t4b::sidecar_path(&json_path);
        t4b::write(
            &cache,
            &space.fingerprint(),
            t4b::SrcStamp::of(&json_path),
            &t4b_path,
        )
        .unwrap();
        b.run("cache/load_json/synthetic", || {
            CacheData::load(&json_path).unwrap().records.len()
        });
        b.run("cache/load_t4b/synthetic", || {
            t4b::read(&t4b_path).unwrap().0.records.len()
        });

        {
            let space2 = Arc::clone(&space);
            let cache2 = Arc::clone(&cache);
            b.throughput("tuning/fresh_alloc/20x50-evals", 20 * 50, move || {
                let mut acc = 0usize;
                for r in 0..20u64 {
                    let mut sim = SimulationRunner::new_unchecked(
                        Arc::clone(&space2),
                        Arc::clone(&cache2),
                    );
                    let mut tuning = Tuning::new(&mut sim, Budget::evals(50));
                    let mut rng = Rng::new(r);
                    while !tuning.done() {
                        tuning.eval(rng.below(n));
                    }
                    acc += tuning.finish().unique_evals;
                }
                std::hint::black_box(acc);
            });
        }
        {
            let space2 = Arc::clone(&space);
            let cache2 = Arc::clone(&cache);
            let mut scratch = TuningScratch::new();
            b.throughput("tuning/scratch_reuse/20x50-evals", 20 * 50, move || {
                let mut acc = 0usize;
                for r in 0..20u64 {
                    let mut sim = SimulationRunner::new_unchecked(
                        Arc::clone(&space2),
                        Arc::clone(&cache2),
                    );
                    let mut tuning =
                        Tuning::with_scratch(&mut sim, Budget::evals(50), &mut scratch);
                    let mut rng = Rng::new(r);
                    while !tuning.done() {
                        tuning.eval(rng.below(n));
                    }
                    acc += tuning.finish().unique_evals;
                }
                std::hint::black_box(acc);
            });
        }
        // The same 64-proposal batches (fresh run each) served by the
        // single-gather fast path vs routed through the bitwise-pinned
        // scalar eval loop — the full-Tuning-accounting view of the
        // eval_batch win.
        let batch_proposals: Vec<Vec<usize>> = (0..20u64)
            .map(|r| {
                let mut rng = Rng::new(r + 100);
                (0..64).map(|_| rng.below(n)).collect()
            })
            .collect();
        for (name, fallback) in [
            ("tuning/batch_vs_scalar/gather", false),
            ("tuning/batch_vs_scalar/scalar", true),
        ] {
            let space2 = Arc::clone(&space);
            let cache2 = Arc::clone(&cache);
            let proposals = batch_proposals.clone();
            let mut scratch = TuningScratch::new();
            b.throughput(name, 20 * 64, move || {
                let mut acc = 0usize;
                for batch in &proposals {
                    let mut sim = SimulationRunner::new_unchecked(
                        Arc::clone(&space2),
                        Arc::clone(&cache2),
                    );
                    let mut tuning =
                        Tuning::with_scratch(&mut sim, Budget::evals(usize::MAX), &mut scratch);
                    tuning.set_scalar_batch_fallback(fallback);
                    acc += tuning.eval_batch(batch).len();
                    acc += tuning.finish().unique_evals;
                }
                std::hint::black_box(acc);
            });
        }

        // Batched campaign scoring: one score_campaign call over
        // 2 spaces x 25 repeats of 40-point traces — the gather step every
        // exhaustive-hypertune configuration pays once per campaign.
        {
            let se = SpaceEval::new(Arc::clone(&space), Arc::clone(&cache), 0.95, 50);
            let spaces_eval = vec![se.clone(), se];
            let repeats = 25usize;
            let traces: Vec<Trace> = (0..spaces_eval.len() * repeats)
                .map(|j| {
                    let se = &spaces_eval[j / repeats];
                    let m = 40usize;
                    let points: Vec<TracePoint> = (0..m)
                        .map(|k| TracePoint {
                            config: k % n,
                            value: se.optimum * (2.0 - k as f64 / m as f64),
                            clock: se.budget_seconds * (k as f64 + 1.0) / m as f64,
                            cached: false,
                        })
                        .collect();
                    Trace {
                        elapsed: se.budget_seconds,
                        unique_evals: m.min(n),
                        points,
                    }
                })
                .collect();
            b.run("methodology/score_batch/2sp-25rep", move || {
                tunetuner::methodology::score_campaign(&spaces_eval, &traces, repeats).len()
            });
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- sweep: full-registry hypertune smoke (PR 5) -------------------------------
    // One sweep_registry pass over every grid-bearing registry optimizer
    // (paper four + extras, ~300 campaigns) on a tiny synthetic training
    // space: the sweep wall-clock lands in the perf trajectory
    // (BENCH_5.json) alongside the PR 4 replay artifacts. Hub-free;
    // setup is filter-gated like the other synthetic groups.
    let wants_sweep = b
        .filter
        .as_ref()
        .map(|f| {
            f.split(',')
                .any(|alt| !alt.is_empty() && "sweep/registry_smoke".contains(alt))
        })
        .unwrap_or(true);
    if wants_sweep {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        let syn_cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
        let train = vec![SpaceEval::new(kernel.space_arc(), syn_cache, 0.95, 15)];
        let observer: Arc<dyn tunetuner::campaign::Observer> =
            Arc::new(tunetuner::campaign::NullObserver);
        b.run("sweep/registry_smoke", || {
            let r = hypertuning::sweep_registry(&train, 1, 3, Arc::clone(&observer)).unwrap();
            r.optimizers.len()
        });
    }

    // ---- metasweep: meta-strategy race smoke (PR 8) --------------------------------
    // Every registered meta-strategy raced against a prebuilt exhaustive
    // reference on the same tiny synthetic training space: the race
    // wall-clock lands in the perf trajectory (BENCH_8.json) next to the
    // sweep smoke it budgets against. The reference sweep is built once
    // outside the timed body — the bench measures the strategies, not the
    // exhaustive grid they are scored against.
    let wants_metasweep = b
        .filter
        .as_ref()
        .map(|f| {
            f.split(',')
                .any(|alt| !alt.is_empty() && "metasweep/registry_smoke".contains(alt))
        })
        .unwrap_or(true);
    if wants_metasweep {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            Arc::clone(&engine),
            NoiseModel::default(),
            42,
        );
        let syn_cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
        let train = vec![SpaceEval::new(kernel.space_arc(), syn_cache, 0.95, 15)];
        let observer: Arc<dyn tunetuner::campaign::Observer> =
            Arc::new(tunetuner::campaign::NullObserver);
        let reference =
            hypertuning::sweep_registry(&train, 2, 3, Arc::clone(&observer)).unwrap();
        let config = hypertuning::MetaSweepConfig::default();
        b.run("metasweep/registry_smoke", || {
            let r = hypertuning::metasweep_registry(
                &train,
                2,
                3,
                &reference,
                &config,
                Arc::clone(&observer),
            )
            .unwrap();
            r.strategies.len()
        });
    }

    // ---- shared hub-backed setup for sim/optimizer/hypertune benches --------------
    let hub = Hub::new(Hub::default_root());
    if !hub.exists("gemm", "A100") {
        println!("(hub missing: run `tunetuner bruteforce` first for sim benches)");
        println!("{:-^78}", " done ");
        b.write_report();
        return;
    }
    let cache = hub.load("gemm", "A100").unwrap();
    let space = kernel.space_arc();

    // ---- sim: replay rate -----------------------------------------------------------
    let n = space.len();
    b.throughput("sim/replay/sequential-10k", 10_000, || {
        let mut sim = SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
        let mut tuning = Tuning::new(&mut sim, Budget::evals(usize::MAX));
        for i in 0..10_000usize {
            tuning.eval(i % n);
        }
    });

    // ---- baseline ------------------------------------------------------------------
    b.run("baseline/SpaceEval::new/gemm@A100", || {
        SpaceEval::new(Arc::clone(&space), Arc::clone(&cache), 0.95, 50).budget_seconds
    });

    // ---- optimizer stepping rate ------------------------------------------------------
    for algo in optimizers::optimizer_names() {
        b.throughput(&format!("optimizer/{algo}/500-evals"), 500, || {
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            let mut tuning = Tuning::new(&mut sim, Budget::evals(500));
            let opt = optimizers::create(algo, &HyperParams::new()).unwrap();
            opt.run(&mut tuning, &mut Rng::new(3));
        });
    }

    // ---- hypertune building blocks ------------------------------------------------------
    let devices: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
    if devices.iter().all(|d| hub.exists("gemm", d)) {
        let evals: Vec<SpaceEval> = devices
            .iter()
            .map(|d| SpaceEval::new(Arc::clone(&space), hub.load("gemm", d).unwrap(), 0.95, 30))
            .collect();
        b.run("hypertune/evaluate_algorithm(ga,6sp,5rep)", || {
            evaluate_algorithm("genetic_algorithm", &HyperParams::new(), &evals, 5, 7)
                .unwrap()
                .score
        });
        b.run("hypertune/exhaustive(da,8cfg,6sp,3rep)", || {
            let hp_space = hypertuning::limited_space("dual_annealing").unwrap();
            hypertuning::exhaustive_tuning("dual_annealing", &hp_space, "limited", &evals, 3, 1)
                .unwrap()
                .best()
                .score
        });
    }
    println!("{:-^78}", " done ");
    b.write_report();
}
