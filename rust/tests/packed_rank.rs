//! Equivalence properties for the packed-rank search-space engine: the
//! mixed-radix rank index must be bit-for-bit interchangeable with the
//! reference hash index it replaced — same `index_of` bijection, same
//! `neighbors` sets (Hamming and Adjacent, including order), and `snap`
//! must always land on a valid configuration. The CSR-backed `neighbors`
//! slices must additionally visit exactly what the probing
//! `for_each_neighbor` visitor yields. Checked on all seed kernels'
//! spaces plus randomized constraint spaces.

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use tunetuner::kernels;
use tunetuner::searchspace::{Constraint, Neighborhood, SearchSpace, TunableParam};
use tunetuner::util::hash::FastMap;
use tunetuner::util::rng::Rng;

/// Reference config→index map built the way the old engine did it:
/// a hash map keyed by the full encoded vector.
fn reference_index(space: &SearchSpace) -> FastMap<Vec<u16>, usize> {
    (0..space.len()).map(|i| (space.encoded_vec(i), i)).collect()
}

/// Reference neighbors built the way the old engine did it: clone a probe
/// vector, mutate one dimension, and look it up in the hash index.
fn reference_neighbors(
    space: &SearchSpace,
    index: &FastMap<Vec<u16>, usize>,
    idx: usize,
    hood: Neighborhood,
) -> Vec<usize> {
    let enc = space.encoded_vec(idx);
    let dims = space.dims();
    let mut out = Vec::new();
    let mut probe = enc.clone();
    for d in 0..dims.len() {
        let orig = enc[d];
        match hood {
            Neighborhood::Hamming => {
                for v in 0..dims[d] as u16 {
                    if v == orig {
                        continue;
                    }
                    probe[d] = v;
                    if let Some(&i) = index.get(&probe) {
                        out.push(i);
                    }
                }
            }
            Neighborhood::Adjacent => {
                if orig > 0 {
                    probe[d] = orig - 1;
                    if let Some(&i) = index.get(&probe) {
                        out.push(i);
                    }
                }
                if (orig as usize) + 1 < dims[d] {
                    probe[d] = orig + 1;
                    if let Some(&i) = index.get(&probe) {
                        out.push(i);
                    }
                }
            }
        }
        probe[d] = orig;
    }
    out
}

/// Step through indices so large spaces stay cheap but every space is
/// covered end to end.
fn probe_indices(len: usize) -> impl Iterator<Item = usize> {
    let step = 1 + len / 200;
    (0..len).step_by(step)
}

fn check_space(space: &SearchSpace, label: &str) {
    let reference = reference_index(space);
    assert_eq!(reference.len(), space.len(), "{label}: index not a bijection");

    let mut rng = Rng::new(0x5EED ^ space.len() as u64);
    for i in probe_indices(space.len()) {
        let enc = space.encoded_vec(i);
        // index_of roundtrip matches the reference hash index exactly.
        assert_eq!(space.index_of(&enc), Some(i), "{label}: roundtrip {i}");
        assert_eq!(reference.get(&enc), Some(&i), "{label}: reference {i}");
        assert_eq!(
            space.index_of_rank(space.rank_of(i)),
            Some(i),
            "{label}: rank roundtrip {i}"
        );

        // Mutated probes agree with the reference on hits AND misses.
        for d in 0..space.dims().len() {
            let mut probe = enc.clone();
            probe[d] = (probe[d] + 1) % space.dims()[d] as u16;
            assert_eq!(
                space.index_of(&probe),
                reference.get(&probe).copied(),
                "{label}: probe {i} dim {d}"
            );
        }

        // Neighbor sets are identical (order included) for both hoods,
        // on all three paths: CSR slice, probing visitor, buffer reuse.
        for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            let got: Vec<usize> = space
                .neighbors(i, hood)
                .iter()
                .map(|&n| n as usize)
                .collect();
            let want = reference_neighbors(space, &reference, i, hood);
            assert_eq!(got, want, "{label}: CSR neighbors {i} {hood:?}");
            let mut visited = Vec::new();
            space.for_each_neighbor(i, hood, |n| visited.push(n));
            assert_eq!(got, visited, "{label}: CSR vs visitor {i} {hood:?}");
            let mut buf = Vec::new();
            space.neighbors_into(i, hood, &mut buf);
            assert_eq!(got, buf, "{label}: CSR vs buffer {i} {hood:?}");
        }

        // snap on jittered lattice points returns valid indices, and is
        // exact on the unjittered point.
        let t: Vec<f64> = enc.iter().map(|&v| v as f64).collect();
        assert_eq!(space.snap(&t, &mut rng), i, "{label}: snap exact {i}");
        let jittered: Vec<f64> = t
            .iter()
            .map(|&v| v + rng.range_f64(-1.5, 1.5))
            .collect();
        let s = space.snap(&jittered, &mut rng);
        assert!(s < space.len(), "{label}: snap jitter {i} -> {s}");
        let se = space.snap_encoded(&enc, &mut rng);
        assert_eq!(se, i, "{label}: snap_encoded exact {i}");
    }

    // Out-of-range encodings never resolve (no rank aliasing).
    if !space.is_empty() {
        let mut probe = space.encoded_vec(0);
        for d in 0..space.dims().len() {
            let orig = probe[d];
            probe[d] = space.dims()[d] as u16;
            assert_eq!(space.index_of(&probe), None, "{label}: oob dim {d}");
            probe[d] = orig;
        }
    }
}

#[test]
fn packed_rank_matches_reference_on_seed_kernels() {
    for name in ["synthetic", "hotspot", "dedispersion", "convolution", "gemm"] {
        let kernel = kernels::kernel_by_name(name).unwrap();
        check_space(kernel.space(), name);
    }
}

#[test]
fn packed_rank_matches_reference_on_random_spaces() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..25 {
        let ndim = 2 + rng.below(4);
        let mut params = Vec::new();
        for d in 0..ndim {
            let card = 2 + rng.below(6);
            let values: Vec<i64> = (0..card)
                .map(|i| ((i + 1) * (1 << rng.below(3))) as i64)
                .collect();
            params.push(TunableParam::new(&format!("p{d}"), values));
        }
        let bound = 1 << (3 + rng.below(5));
        let constraints =
            vec![Constraint::parse(&format!("p0 * p1 <= {bound}")).unwrap()];
        let space = match SearchSpace::build("prop", params, constraints) {
            Ok(s) if !s.is_empty() => s,
            _ => continue,
        };
        check_space(&space, &format!("random case {case}"));
    }
}

#[test]
fn all_index_variants_match_reference_on_generated_spaces() {
    // The full reference battery on spacegen spaces, once per
    // (index kind x flat policy) build: every variant must be a drop-in
    // replacement, including with the flat decode buffer elided.
    use tunetuner::searchspace::{
        BuildOptions, ConstraintFamily, FlatPolicy, IndexKind, SpaceGenSpec,
    };
    let cases = [
        (ConstraintFamily::Hash, vec![16usize, 16, 12], 0.08),
        (ConstraintFamily::Product, vec![24, 24, 8], 0.05),
        (ConstraintFamily::Mixed, vec![12, 12, 12, 6], 0.10),
    ];
    for (family, dims, validity) in cases {
        let spec = SpaceGenSpec::new(dims, validity, family, 3);
        for index in [IndexKind::Bitset, IndexKind::Map, IndexKind::Compressed] {
            for flat in [FlatPolicy::Materialize, FlatPolicy::Elide] {
                let space = spec.build_with(BuildOptions { index, flat }).unwrap();
                assert!(!space.is_empty(), "{} produced an empty space", spec.name());
                assert_eq!(space.index_kind(), index);
                assert_eq!(space.has_flat(), flat == FlatPolicy::Materialize);
                check_space(
                    &space,
                    &format!("{}[{index:?},{flat:?}]", spec.name()),
                );
            }
        }
    }
}

#[test]
fn random_neighbor_stays_in_neighborhood() {
    // random_neighbor must return either a true neighbor or (only when the
    // neighborhood is empty) some valid config.
    let kernel = kernels::kernel_by_name("synthetic").unwrap();
    let space = kernel.space();
    let reference = reference_index(space);
    let mut rng = Rng::new(42);
    for _ in 0..500 {
        let idx = space.random(&mut rng);
        for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            let n = space.random_neighbor(idx, hood, &mut rng);
            assert!(n < space.len());
            let hood_set = reference_neighbors(space, &reference, idx, hood);
            if !hood_set.is_empty() {
                assert!(
                    hood_set.contains(&n),
                    "{n} not a {hood:?} neighbor of {idx}"
                );
            }
        }
    }
}
