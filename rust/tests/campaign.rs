//! Campaign API end-to-end tests.
//!
//! The refactor contract: `Campaign` (persistent executor, observer
//! events, typed errors) must reproduce the pre-refactor
//! `evaluate_algorithm` pipeline **byte-for-byte**. The reference
//! implementation below is a faithful copy of the old per-call
//! `thread::scope` evaluator; the tests pin the new path against it on
//! the synthetic kernel in simulation mode at quick scale.

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(clippy::needless_range_loop, clippy::type_complexity)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use tunetuner::campaign::{Campaign, Executor, Observer};
use tunetuner::dataset::bruteforce;
use tunetuner::dataset::cache::CacheData;
use tunetuner::gpu::specs::{A100, MI250X, W6600};
use tunetuner::kernels;
use tunetuner::methodology::{evaluate_algorithm, AggregateResult, SpaceEval};
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::perfmodel::NoiseModel;
use tunetuner::runner::live::FRAMEWORK_OVERHEAD;
use tunetuner::runner::{
    Budget, EvalResult, LiveRunner, Runner, SimulationRunner, Trace, Tuning, TuningScratch,
};
use tunetuner::runtime::Engine;
use tunetuner::searchspace::SearchSpace;
use tunetuner::util::rng::{mix64, Rng};

/// Three synthetic-kernel spaces on distinct simulated devices.
fn spaces() -> &'static Vec<SpaceEval> {
    static SPACES: OnceLock<Vec<SpaceEval>> = OnceLock::new();
    SPACES.get_or_init(|| {
        let engine = Arc::new(Engine::native());
        [&A100, &MI250X, &W6600]
            .iter()
            .map(|dev| {
                let kernel = kernels::kernel_by_name("synthetic").unwrap();
                let mut live = LiveRunner::new(
                    kernels::kernel_by_name("synthetic").unwrap(),
                    dev,
                    Arc::clone(&engine),
                    NoiseModel::default(),
                    42,
                );
                let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                SpaceEval::new(kernel.space_arc(), cache, 0.95, 25)
            })
            .collect()
    })
}

/// The pre-refactor `evaluate_algorithm`: a fresh `thread::scope` per
/// call, lock-free scatter/gather into job slots, seeds derived per
/// (seed, space, repeat). Kept verbatim as the golden reference.
fn reference_evaluate(
    algo: &str,
    hp: &HyperParams,
    spaces: &[SpaceEval],
    repeats: usize,
    seed: u64,
) -> AggregateResult {
    optimizers::create(algo, hp).unwrap();
    let n_jobs = spaces.len() * repeats;
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n_jobs.max(1));

    let mut slots: Vec<Option<Trace>> = Vec::new();
    slots.resize_with(n_jobs, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let opt = optimizers::create(algo, hp).expect("validated above");
                    let mut local: Vec<(usize, Trace)> = Vec::new();
                    loop {
                        let job = next.fetch_add(1, Ordering::Relaxed);
                        if job >= n_jobs {
                            break;
                        }
                        let s = job / repeats;
                        let r = job % repeats;
                        let se = &spaces[s];
                        let mut sim = SimulationRunner::new_unchecked(
                            Arc::clone(&se.space),
                            Arc::clone(&se.cache),
                        );
                        let budget = Budget::seconds(se.budget_seconds)
                            .with_proposal_cap(4 * se.space.len() + 10_000);
                        let mut tuning = Tuning::new(&mut sim, budget);
                        let mut rng = Rng::new(mix64(seed, mix64(s as u64, r as u64)));
                        opt.run(&mut tuning, &mut rng);
                        local.push((job, tuning.finish()));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (job, trace) in h.join().expect("evaluation worker panicked") {
                slots[job] = Some(trace);
            }
        }
    });

    let mut per_space_scores = Vec::with_capacity(spaces.len());
    for (s, se) in spaces.iter().enumerate() {
        let ts: Vec<Trace> = slots[s * repeats..(s + 1) * repeats]
            .iter_mut()
            .map(|t| t.take().expect("job slot unfilled"))
            .collect();
        per_space_scores.push(se.score_traces(&ts));
    }
    let points = per_space_scores[0].len();
    let aggregate_curve: Vec<f64> = (0..points)
        .map(|t| {
            per_space_scores.iter().map(|s| s[t]).sum::<f64>() / per_space_scores.len() as f64
        })
        .collect();
    let score = aggregate_curve.iter().sum::<f64>() / aggregate_curve.len() as f64;
    AggregateResult {
        per_space_scores,
        aggregate_curve,
        score,
    }
}

fn assert_bitwise_equal(a: &AggregateResult, b: &AggregateResult, tag: &str) {
    assert_eq!(a.score.to_bits(), b.score.to_bits(), "{tag}: score drift");
    assert_eq!(
        a.aggregate_curve.len(),
        b.aggregate_curve.len(),
        "{tag}: curve length"
    );
    for (i, (x, y)) in a.aggregate_curve.iter().zip(&b.aggregate_curve).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: curve point {i}");
    }
    for (s, (xs, ys)) in a
        .per_space_scores
        .iter()
        .zip(&b.per_space_scores)
        .enumerate()
    {
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: space {s} point {i}");
        }
    }
}

/// Every algorithm, through the campaign path, reproduces the
/// pre-refactor evaluator bit-for-bit.
#[test]
fn campaign_reproduces_prerefactor_scores_bitwise() {
    for algo in ["random_search", "pso", "genetic_algorithm", "simulated_annealing"] {
        let reference = reference_evaluate(algo, &HyperParams::new(), spaces(), 8, 7);
        let campaign = Campaign::new(algo)
            .space_evals(spaces().clone())
            .repeats(8)
            .seed(7)
            .run()
            .unwrap();
        assert_bitwise_equal(&campaign.aggregate, &reference, algo);
        // And the evaluate_algorithm wrapper is the same thing.
        let wrapper = evaluate_algorithm(algo, &HyperParams::new(), spaces(), 8, 7).unwrap();
        assert_bitwise_equal(&wrapper, &reference, algo);
    }
}

/// Non-default hyperparameters flow through identically.
#[test]
fn campaign_reproduces_prerefactor_scores_with_hyperparams() {
    let hp = HyperParams::new()
        .set("method", "two_point")
        .set("popsize", 10i64)
        .set("mutation_chance", 20i64);
    let reference = reference_evaluate("genetic_algorithm", &hp, spaces(), 6, 13);
    let campaign = Campaign::new("genetic_algorithm")
        .hyperparams(hp)
        .space_evals(spaces().clone())
        .repeats(6)
        .seed(13)
        .run()
        .unwrap();
    assert_bitwise_equal(&campaign.aggregate, &reference, "ga+hp");
}

/// The pre-SimTable, pre-scratch simulation path, verbatim: a pointer
/// chase into the AoS `ConfigRecord` plus an observation-vector re-sum
/// per `evaluate_lite` (the old `SimulationRunner` hot path). Driven
/// through `Tuning::new` (fresh space-sized buffers per run), it is the
/// "before" side of the PR-4 replay-equivalence pin.
struct RecordWalkRunner {
    space: Arc<SearchSpace>,
    cache: Arc<CacheData>,
}

impl Runner for RecordWalkRunner {
    fn space(&self) -> &SearchSpace {
        &self.space
    }

    fn evaluate(&mut self, config_idx: usize) -> EvalResult {
        let rec = &self.cache.records[config_idx];
        EvalResult {
            value: rec.value,
            observations: rec.observations.clone(),
            compile_time: rec.compile_time,
            run_time: rec.observations.iter().sum(),
            overhead: FRAMEWORK_OVERHEAD,
            valid: rec.valid,
        }
    }

    fn label(&self) -> String {
        "record-walk reference".into()
    }

    fn evaluate_lite(&mut self, config_idx: usize) -> (f64, f64) {
        let rec = &self.cache.records[config_idx];
        let cost = rec.compile_time + rec.observations.iter().sum::<f64>() + FRAMEWORK_OVERHEAD;
        (rec.value, cost)
    }
}

/// SimTable + pooled scratch replay bit-identically to the pre-change
/// record-walk + fresh-allocation path: same seeds, same values, same
/// clocks (asserted bitwise — stronger than the required 1e-12).
#[test]
fn simtable_and_pooled_scratch_replay_bit_identical_traces() {
    let mut scratch = TuningScratch::new();
    for (s, se) in spaces().iter().enumerate() {
        for r in 0..3usize {
            for algo in ["random_search", "genetic_algorithm", "mls"] {
                let opt = optimizers::create(algo, &HyperParams::new()).unwrap();
                let budget = Budget::seconds(se.budget_seconds)
                    .with_proposal_cap(4 * se.space.len() + 10_000);
                let seed = mix64(17, mix64(s as u64, r as u64));

                let mut reference = RecordWalkRunner {
                    space: Arc::clone(&se.space),
                    cache: Arc::clone(&se.cache),
                };
                let mut t_ref = Tuning::new(&mut reference, budget);
                opt.run(&mut t_ref, &mut Rng::new(seed));
                let t_ref = t_ref.finish();

                let mut sim = SimulationRunner::new_unchecked(
                    Arc::clone(&se.space),
                    Arc::clone(&se.cache),
                );
                let mut t_new = Tuning::with_scratch(&mut sim, budget, &mut scratch);
                opt.run(&mut t_new, &mut Rng::new(seed));
                let t_new = t_new.finish();

                let tag = format!("space {s} repeat {r} {algo}");
                assert_eq!(t_ref.points.len(), t_new.points.len(), "{tag}");
                assert_eq!(t_ref.unique_evals, t_new.unique_evals, "{tag}");
                assert_eq!(t_ref.elapsed.to_bits(), t_new.elapsed.to_bits(), "{tag}");
                for (p, (a, b)) in t_ref.points.iter().zip(&t_new.points).enumerate() {
                    assert_eq!(a.config, b.config, "{tag} point {p}");
                    assert_eq!(a.value.to_bits(), b.value.to_bits(), "{tag} point {p}");
                    assert!((a.clock - b.clock).abs() <= 1e-12, "{tag} point {p}");
                    assert_eq!(a.clock.to_bits(), b.clock.to_bits(), "{tag} point {p}");
                    assert_eq!(a.cached, b.cached, "{tag} point {p}");
                }
            }
        }
    }
}

/// The same pin at full `Campaign::run` granularity: the record-walking
/// pre-change evaluator (fresh buffers, per-lookup re-sums) and the
/// current campaign path (SimTable + per-worker pooled scratch) produce
/// bit-identical aggregate scores.
#[test]
fn campaign_matches_prechange_record_walk_evaluator() {
    let (algo, repeats, seed) = ("pso", 6, 29u64);
    let opt = optimizers::create(algo, &HyperParams::new()).unwrap();
    let mut per_space_scores = Vec::new();
    for (s, se) in spaces().iter().enumerate() {
        let mut traces = Vec::with_capacity(repeats);
        for r in 0..repeats {
            let mut reference = RecordWalkRunner {
                space: Arc::clone(&se.space),
                cache: Arc::clone(&se.cache),
            };
            let budget = Budget::seconds(se.budget_seconds)
                .with_proposal_cap(4 * se.space.len() + 10_000);
            let mut tuning = Tuning::new(&mut reference, budget);
            let mut rng = Rng::new(mix64(seed, mix64(s as u64, r as u64)));
            opt.run(&mut tuning, &mut rng);
            traces.push(tuning.finish());
        }
        per_space_scores.push(se.score_traces(&traces));
    }
    let reference = AggregateResult::from_per_space_scores(per_space_scores);

    let campaign = Campaign::new(algo)
        .space_evals(spaces().clone())
        .repeats(repeats)
        .seed(seed)
        .run()
        .unwrap();
    assert_bitwise_equal(&campaign.aggregate, &reference, "prechange record walk");
}

/// The same campaign is bit-stable across executor pool shapes (the
/// seeds come from job indices, not threads).
#[test]
fn campaign_bit_stable_across_executors() {
    let base = Campaign::new("dual_annealing")
        .space_evals(spaces().clone())
        .repeats(5)
        .seed(21);
    let on_global = base.clone().run().unwrap();
    for workers in [0, 1, 7] {
        let on_pool = base
            .clone()
            .executor(Arc::new(Executor::new(workers)))
            .run()
            .unwrap();
        assert_bitwise_equal(
            &on_pool.aggregate,
            &on_global.aggregate,
            &format!("pool size {workers}"),
        );
    }
}

/// Observer event stream: submitting-thread events are totally ordered,
/// worker events respect the documented partial order, and counts match
/// spaces × repeats exactly.
#[derive(Default)]
struct Recorder {
    events: Mutex<Vec<String>>,
}

impl Observer for Recorder {
    fn campaign_started(&self, algo: &str, _hp_key: &str, spaces: usize, repeats: usize) {
        self.push(format!("campaign_started {algo} {spaces}x{repeats}"));
    }
    fn space_started(&self, s: usize, _label: &str, _budget: f64) {
        self.push(format!("space_started {s}"));
    }
    fn run_started(&self, s: usize, r: usize) {
        self.push(format!("run_started {s}.{r}"));
    }
    fn trace_completed(&self, s: usize, r: usize, best: f64, unique: usize, _elapsed: f64) {
        assert!(best.is_finite() && unique > 0);
        self.push(format!("trace_completed {s}.{r}"));
    }
    fn space_scored(&self, s: usize, _label: &str, _mean: f64) {
        self.push(format!("space_scored {s}"));
    }
    fn campaign_finished(&self, _score: f64, _wall: f64) {
        self.push("campaign_finished".to_string());
    }
}

impl Recorder {
    fn push(&self, e: String) {
        self.events.lock().unwrap().push(e);
    }
}

#[test]
fn observer_event_order_and_counts() {
    let rec = Arc::new(Recorder::default());
    let (n_spaces, repeats) = (3usize, 4usize);
    Campaign::new("mls")
        .space_evals(spaces().clone())
        .repeats(repeats)
        .observer(Arc::clone(&rec) as Arc<dyn Observer>)
        .run()
        .unwrap();
    let events = rec.events.lock().unwrap().clone();

    // Bookends.
    assert_eq!(events.first().unwrap(), "campaign_started mls 3x4");
    assert_eq!(events.last().unwrap(), "campaign_finished");

    // Exact counts: one start/completion per (space, repeat), one
    // started/scored per space.
    let count = |prefix: &str| events.iter().filter(|e| e.starts_with(prefix)).count();
    assert_eq!(count("space_started"), n_spaces);
    assert_eq!(count("space_scored"), n_spaces);
    assert_eq!(count("run_started"), n_spaces * repeats);
    assert_eq!(count("trace_completed"), n_spaces * repeats);

    // Partial order: all space_started before any run event; every run's
    // start before its completion; all completions before any scoring;
    // scoring in space order.
    let pos = |e: &str| events.iter().position(|x| x == e).unwrap();
    let last_space_started = events
        .iter()
        .rposition(|e| e.starts_with("space_started"))
        .unwrap();
    let first_run = events
        .iter()
        .position(|e| e.starts_with("run_started"))
        .unwrap();
    assert!(last_space_started < first_run);
    for s in 0..n_spaces {
        for r in 0..repeats {
            assert!(
                pos(&format!("run_started {s}.{r}")) < pos(&format!("trace_completed {s}.{r}"))
            );
        }
    }
    let last_trace = events
        .iter()
        .rposition(|e| e.starts_with("trace_completed"))
        .unwrap();
    let first_scored = events
        .iter()
        .position(|e| e.starts_with("space_scored"))
        .unwrap();
    assert!(last_trace < first_scored);
    for s in 1..n_spaces {
        assert!(pos(&format!("space_scored {}", s - 1)) < pos(&format!("space_scored {s}")));
    }
}

/// `config_scored` threads through the hypertuning layer: one event per
/// configuration, in enumeration order.
#[test]
fn exhaustive_tuning_emits_config_scored() {
    #[derive(Default)]
    struct Configs(Mutex<Vec<(usize, f64)>>);
    impl Observer for Configs {
        fn config_scored(&self, idx: usize, _hp_key: &str, score: f64) {
            self.0.lock().unwrap().push((idx, score));
        }
    }
    let obs = Arc::new(Configs::default());
    let hp_space = tunetuner::hypertuning::limited_space("dual_annealing").unwrap();
    let results = tunetuner::hypertuning::exhaustive_tuning_observed(
        "dual_annealing",
        &hp_space,
        "limited",
        &spaces()[..1],
        2,
        5,
        Arc::clone(&obs) as Arc<dyn Observer>,
    )
    .unwrap();
    let seen = obs.0.lock().unwrap().clone();
    assert_eq!(seen.len(), hp_space.len());
    for (i, (idx, score)) in seen.iter().enumerate() {
        assert_eq!(*idx, i);
        assert_eq!(score.to_bits(), results.results[i].score.to_bits());
    }
}

/// Typed errors at the library boundary.
#[test]
fn campaign_errors_are_typed() {
    use tunetuner::TuneError;
    let err = Campaign::new("warp_drive")
        .space_evals(spaces().clone())
        .run()
        .unwrap_err();
    assert!(matches!(err, TuneError::UnknownAlgorithm { .. }), "{err:#}");
    let err = Campaign::new("pso")
        .hyperparams(HyperParams::new().set("warp", 9.9))
        .space_evals(spaces().clone())
        .run()
        .unwrap_err();
    assert!(matches!(err, TuneError::SchemaViolation(_)), "{err:#}");
}
