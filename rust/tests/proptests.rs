//! Property-based tests. The proptest crate is unavailable offline, so
//! these are hand-rolled randomized-property loops driven by the crate's
//! own deterministic RNG: each property is checked over many generated
//! cases, and failures print the seed for replay.

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use std::collections::BTreeMap;
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::searchspace::{Constraint, Neighborhood, SearchSpace, TunableParam, Value};
use tunetuner::util::json::{self, Json};
use tunetuner::util::rng::Rng;
use tunetuner::util::stats;

/// Random search-space ingredients: 2–5 dims, small value lists, and a
/// random product constraint (shared by [`random_space`] and the
/// index-variant equivalence property, which rebuilds the same space
/// under every [`tunetuner::searchspace::BuildOptions`] combination).
fn random_space_parts(rng: &mut Rng) -> (Vec<TunableParam>, Vec<Constraint>) {
    let ndim = 2 + rng.below(4);
    let mut params = Vec::new();
    for d in 0..ndim {
        let card = 2 + rng.below(5);
        let values: Vec<i64> = (0..card).map(|i| ((i + 1) * (1 << rng.below(3))) as i64).collect();
        params.push(TunableParam::new(&format!("p{d}"), values));
    }
    // Constrain the product of the first two dims.
    let bound = 1 << (3 + rng.below(5));
    let constraints = vec![Constraint::parse(&format!("p0 * p1 <= {bound}")).unwrap()];
    (params, constraints)
}

/// Generate a random search space from [`random_space_parts`].
fn random_space(rng: &mut Rng) -> SearchSpace {
    let (params, constraints) = random_space_parts(rng);
    match SearchSpace::build("prop", params, constraints) {
        Ok(s) if !s.is_empty() => s,
        _ => {
            // Regenerate on empty spaces (rare with these bounds).
            random_space(rng)
        }
    }
}

/// Every (index kind x flat policy) build of the same space is bitwise
/// interchangeable: same ranks, same encodings, same index_of_rank
/// answers, and identical same-seed random_neighbor walks and snap
/// streams (the optimizer-facing RNG-consuming paths).
#[test]
fn prop_index_variants_and_flat_policies_bitwise_equivalent() {
    use tunetuner::searchspace::{BuildOptions, FlatPolicy, IndexKind};
    let mut rng = Rng::new(0x1DE);
    for case in 0..20u64 {
        let (params, constraints) = random_space_parts(&mut rng);
        let base = match SearchSpace::build("prop", params.clone(), constraints.clone()) {
            Ok(s) if !s.is_empty() => s,
            _ => continue,
        };
        for index in [IndexKind::Bitset, IndexKind::Map, IndexKind::Compressed] {
            for flat in [FlatPolicy::Materialize, FlatPolicy::Elide] {
                let v = SearchSpace::build_with(
                    "prop",
                    params.clone(),
                    constraints.clone(),
                    BuildOptions { index, flat },
                )
                .unwrap();
                let ctx = format!("case {case} {index:?} {flat:?}");
                assert_eq!(v.len(), base.len(), "{ctx}");
                for i in 0..base.len() {
                    assert_eq!(v.rank_of(i), base.rank_of(i), "{ctx} config {i}");
                    assert_eq!(v.encoded_vec(i), base.encoded_vec(i), "{ctx} config {i}");
                    assert_eq!(v.index_of_rank(base.rank_of(i)), Some(i), "{ctx} config {i}");
                }
                // Same-seed random-neighbor walks are identical streams.
                let (mut ra, mut rb) = (Rng::new(case + 1), Rng::new(case + 1));
                let (mut ca, mut cb) = (0usize, 0usize);
                for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
                    for step in 0..40 {
                        ca = base.random_neighbor(ca, hood, &mut ra);
                        cb = v.random_neighbor(cb, hood, &mut rb);
                        assert_eq!(ca, cb, "{ctx} {hood:?} step {step}");
                    }
                }
                // Same-seed snap streams on shared off-lattice targets.
                let (mut ra, mut rb) = (Rng::new(case + 77), Rng::new(case + 77));
                for k in 0..20usize {
                    let t: Vec<f64> = base
                        .dims()
                        .iter()
                        .enumerate()
                        .map(|(d, &dim)| ((k * 7 + d * 3) % (dim + 2)) as f64 - 0.7)
                        .collect();
                    assert_eq!(
                        base.snap(&t, &mut ra),
                        v.snap(&t, &mut rb),
                        "{ctx} snap {k}"
                    );
                }
            }
        }
    }
}

/// Search-space invariants: indexing is a bijection, every config
/// satisfies the constraints, neighbors are valid and symmetric.
#[test]
fn prop_space_invariants() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..30 {
        let space = random_space(&mut rng);
        // Bijection.
        for i in (0..space.len()).step_by(1 + space.len() / 50) {
            assert_eq!(space.index_of(space.encoded(i)), Some(i), "case {case}");
            // Constraint satisfaction.
            let env: BTreeMap<String, Value> = space.named_values(i).into_iter().collect();
            for c in &space.constraints {
                assert!(c.eval_map(&env).unwrap(), "case {case} config {i}");
            }
        }
        // Neighbor validity + symmetry (Hamming is symmetric by definition).
        let probe = space.len() / 2;
        for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            for &n in space.neighbors(probe, hood) {
                let n = n as usize;
                assert!(n < space.len());
                assert_ne!(n, probe);
                if hood == Neighborhood::Hamming {
                    assert!(
                        space.neighbors(n, hood).contains(&(probe as u32)),
                        "case {case}: hamming not symmetric"
                    );
                }
            }
        }
    }
}

/// The CSR-backed `neighbors` slices visit exactly the same indices, in
/// the same order, as the probing `for_each_neighbor` visitor — on every
/// config of many randomized constraint spaces.
#[test]
fn prop_csr_slices_match_visitor() {
    let mut rng = Rng::new(0xC5A);
    for case in 0..20 {
        let space = random_space(&mut rng);
        let mut visited = Vec::new();
        for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            for i in 0..space.len() {
                visited.clear();
                space.for_each_neighbor(i, hood, |n| visited.push(n));
                let slice: Vec<usize> = space
                    .neighbors(i, hood)
                    .iter()
                    .map(|&n| n as usize)
                    .collect();
                assert_eq!(slice, visited, "case {case} config {i} {hood:?}");
            }
        }
    }
}

/// snap() always returns a valid index, and is exact when the target is a
/// valid lattice point.
#[test]
fn prop_snap_valid_and_exact() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..30 {
        let space = random_space(&mut rng);
        for _ in 0..20 {
            // Arbitrary continuous points.
            let target: Vec<f64> = space
                .dims()
                .iter()
                .map(|&d| rng.range_f64(-1.0, d as f64 + 1.0))
                .collect();
            let idx = space.snap(&target, &mut rng);
            assert!(idx < space.len());
            // Exact valid lattice point -> identity.
            let exact = space.random(&mut rng);
            let t: Vec<f64> = space.encoded(exact).iter().map(|&v| v as f64).collect();
            assert_eq!(space.snap(&t, &mut rng), exact);
        }
    }
}

/// JSON roundtrip over randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => {
                // Mix of integers, fractions, negatives, exponents.
                let x = match rng.below(4) {
                    0 => rng.below(1000) as f64,
                    1 => -(rng.below(1000) as f64),
                    2 => rng.next_f64() * 1e6 - 5e5,
                    _ => rng.next_f64() * 1e-6,
                };
                Json::Num(x)
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            'é' // exercise multi-byte paths
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), gen(rng, depth - 1));
                }
                o
            }
        }
    }
    let mut rng = Rng::new(0xD00D);
    for case in 0..300 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(doc, back, "case {case}: {text}");
        // Pretty form parses to the same value.
        assert_eq!(json::parse(&doc.to_pretty()).unwrap(), doc);
    }
}

/// Every optimizer respects arbitrary budgets and never evaluates an
/// out-of-range configuration (checked via the trace).
#[test]
fn prop_optimizer_budget_and_range() {
    use std::sync::Arc;
    use tunetuner::dataset::cache::{CacheData, ConfigRecord};
    use tunetuner::runner::{Budget, SimulationRunner, Tuning};

    let mut rng = Rng::new(0xFEED);
    for case in 0..12 {
        let space = Arc::new(random_space(&mut rng));
        // Synthetic cache over the space with a rugged value function.
        let records: Vec<ConfigRecord> = (0..space.len())
            .map(|i| {
                let v = 1.0 + ((i as f64 * 0.7919).sin() * 0.5 + 0.5);
                ConfigRecord {
                    key: space.key(i),
                    value: v,
                    observations: vec![v],
                    compile_time: 1.0,
                    valid: true,
                }
            })
            .collect();
        let cache = Arc::new(CacheData::new(
            "prop",
            "x",
            "",
            0,
            1,
            0.0,
            space.params.iter().map(|p| p.name.clone()).collect(),
            records,
        ));
        let budget = 1 + rng.below(40);
        for name in optimizers::optimizer_names() {
            let mut sim = SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            let mut tuning = Tuning::new(&mut sim, Budget::evals(budget));
            let opt = optimizers::create(name, &HyperParams::new()).unwrap();
            let mut orng = Rng::new(case as u64 * 31 + 7);
            opt.run(&mut tuning, &mut orng);
            let trace = tuning.finish();
            assert!(
                trace.unique_evals <= budget,
                "case {case} {name}: {} > {budget}",
                trace.unique_evals
            );
            for p in &trace.points {
                assert!(p.config < space.len(), "case {case} {name}");
            }
            // best_at is monotone non-increasing in t.
            let mut prev = f64::INFINITY;
            for k in 1..=8 {
                let t = trace.elapsed * k as f64 / 8.0;
                if let Some(b) = trace.best_at(t) {
                    assert!(b <= prev + 1e-12);
                    prev = b;
                }
            }
        }
    }
}

/// Percentile/midrank properties on random data.
#[test]
fn prop_stats_invariants() {
    let mut rng = Rng::new(0xACE);
    for _ in 0..100 {
        let n = 1 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
        // Percentile bounds and monotonicity in p.
        let p0 = stats::percentile(&xs, 0.0);
        let p50 = stats::percentile(&xs, 50.0);
        let p100 = stats::percentile(&xs, 100.0);
        assert!(p0 <= p50 && p50 <= p100);
        assert_eq!(p0, stats::min(&xs));
        assert_eq!(p100, stats::max(&xs));
        // Midranks sum to n(n+1)/2.
        let ranks = stats::midranks(&xs);
        let sum: f64 = ranks.iter().sum();
        let expect = (n * (n + 1)) as f64 / 2.0;
        assert!((sum - expect).abs() < 1e-6, "{sum} != {expect}");
    }
}

/// Random `CacheData` through the two on-disk formats — JSON roundtrip
/// vs T4B roundtrip — must be field-for-field identical (infinities and
/// empty observation vectors included) and must replay *identical* sim
/// traces, cached revisits and invalid configs included.
#[test]
fn prop_t4b_and_json_load_paths_replay_identical_traces() {
    use std::sync::Arc;
    use tunetuner::dataset::cache::{CacheData, ConfigRecord};
    use tunetuner::dataset::t4b;
    use tunetuner::runner::{Budget, SimulationRunner, Tuning};

    let mut rng = Rng::new(0x74B);
    for case in 0..15 {
        let space = Arc::new(random_space(&mut rng));
        // Random landscape: mixed valid/invalid, varying observation
        // counts (invalid configs carry none — matching what bruteforce
        // writes, and what the JSON format can represent).
        let records: Vec<ConfigRecord> = (0..space.len())
            .map(|i| {
                let valid = !rng.chance(0.2);
                let n_obs = 1 + rng.below(4);
                let observations: Vec<f64> = if valid {
                    (0..n_obs).map(|_| rng.range_f64(1e-4, 2.0)).collect()
                } else {
                    Vec::new()
                };
                let value = if valid {
                    observations.iter().sum::<f64>() / observations.len() as f64
                } else {
                    f64::INFINITY
                };
                ConfigRecord {
                    key: space.key(i),
                    value,
                    observations,
                    compile_time: rng.range_f64(0.1, 5.0),
                    valid,
                }
            })
            .collect();
        let original = CacheData::new(
            "prop",
            "x",
            "t4b property",
            case as u64,
            3,
            rng.range_f64(0.0, 1e6),
            space.params.iter().map(|p| p.name.clone()).collect(),
            records,
        );

        // The two load paths.
        let via_json = CacheData::from_json(&original.to_json()).unwrap();
        let (via_t4b, fp, _) =
            t4b::decode(&t4b::encode(&original, "prop-fp", t4b::SrcStamp::NONE)).unwrap();
        assert_eq!(fp, "prop-fp", "case {case}");

        assert_eq!(via_json.records.len(), via_t4b.records.len(), "case {case}");
        for (i, (a, b)) in via_json.records.iter().zip(&via_t4b.records).enumerate() {
            assert_eq!(a.key, b.key, "case {case} record {i}");
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "case {case} record {i}");
            assert_eq!(a.observations, b.observations, "case {case} record {i}");
            assert_eq!(
                a.compile_time.to_bits(),
                b.compile_time.to_bits(),
                "case {case} record {i}"
            );
            assert_eq!(a.valid, b.valid, "case {case} record {i}");
        }
        assert_eq!(
            via_json.bruteforce_seconds.to_bits(),
            via_t4b.bruteforce_seconds.to_bits()
        );
        assert_eq!(via_json.param_names, via_t4b.param_names);
        assert_eq!(via_json.space_seed, via_t4b.space_seed);

        // Identical sim traces from both load paths: a revisit-heavy
        // pseudorandom eval walk, bit-compared point by point.
        let n = space.len();
        let seq: Vec<usize> = (0..80).map(|i| (i * 13 + case * 7) % n).collect();
        let replay = |cache: CacheData| {
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::new(cache));
            let mut tuning = Tuning::new(&mut sim, Budget::evals(usize::MAX));
            for &i in &seq {
                tuning.eval(i);
            }
            tuning.finish()
        };
        let tj = replay(via_json);
        let tb = replay(via_t4b);
        assert_eq!(tj.points.len(), tb.points.len(), "case {case}");
        assert_eq!(tj.unique_evals, tb.unique_evals, "case {case}");
        assert_eq!(tj.elapsed.to_bits(), tb.elapsed.to_bits(), "case {case}");
        for (p, (a, b)) in tj.points.iter().zip(&tb.points).enumerate() {
            assert_eq!(a.config, b.config, "case {case} point {p}");
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "case {case} point {p}");
            assert_eq!(a.clock.to_bits(), b.clock.to_bits(), "case {case} point {p}");
            assert_eq!(a.cached, b.cached, "case {case} point {p}");
        }
    }
}

/// Build a synthetic brute-force cache over `space` with a rugged value
/// landscape, mixed validity, and varied per-config costs.
fn synthetic_cache(
    space: &tunetuner::searchspace::SearchSpace,
    rng: &mut Rng,
    invalid_frac: f64,
) -> tunetuner::dataset::cache::CacheData {
    use tunetuner::dataset::cache::{CacheData, ConfigRecord};
    let records: Vec<ConfigRecord> = (0..space.len())
        .map(|i| {
            let valid = !rng.chance(invalid_frac);
            let v = if valid {
                1.0 + ((i as f64 * 0.7919).sin() * 0.5 + 0.5)
            } else {
                f64::INFINITY
            };
            ConfigRecord {
                key: space.key(i),
                value: v,
                observations: if valid { vec![v] } else { Vec::new() },
                compile_time: 0.5 + (i % 7) as f64 * 0.3,
                valid,
            }
        })
        .collect();
    CacheData::new(
        "prop",
        "x",
        "",
        0,
        1,
        0.0,
        space.params.iter().map(|p| p.name.clone()).collect(),
        records,
    )
}

fn assert_traces_bitwise_eq(a: &tunetuner::runner::Trace, b: &tunetuner::runner::Trace, ctx: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{ctx}");
    assert_eq!(a.unique_evals, b.unique_evals, "{ctx}");
    assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits(), "{ctx}");
    for (p, (x, y)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(x.config, y.config, "{ctx} point {p}");
        assert_eq!(x.value.to_bits(), y.value.to_bits(), "{ctx} point {p}");
        assert_eq!(x.clock.to_bits(), y.clock.to_bits(), "{ctx} point {p}");
        assert_eq!(x.cached, y.cached, "{ctx} point {p}");
    }
}

/// `eval_batch` is exactly the scalar loop `for i in batch { if done()
/// { break; } eval(i); }` — same returned values, same trace points,
/// same clocks, same budget-expiry truncation — over random spaces,
/// random budget kinds (unique-eval, wall-clock, proposal-cap) and
/// revisit-heavy batch interleavings, empty batches included.
#[test]
fn prop_eval_batch_matches_scalar_loop_bitwise() {
    use std::sync::Arc;
    use tunetuner::runner::{Budget, SimulationRunner, Tuning};

    let mut rng = Rng::new(0xBA7C);
    for case in 0..20usize {
        let space = Arc::new(random_space(&mut rng));
        let n = space.len();
        let cache = Arc::new(synthetic_cache(&space, &mut rng, 0.15));

        // Random batch plan: mixed sizes, skewed toward a small index
        // pool so in-batch duplicates and cross-batch revisits are dense.
        let batches: Vec<Vec<usize>> = (0..8)
            .map(|_| {
                let len = rng.below(2 * n.min(40) + 1);
                (0..len)
                    .map(|_| {
                        if rng.chance(0.4) {
                            rng.below(1 + n / 3)
                        } else {
                            rng.below(n)
                        }
                    })
                    .collect()
            })
            .collect();
        let budget = match rng.below(3) {
            0 => Budget::evals(1 + rng.below(n)),
            1 => Budget::seconds(rng.range_f64(0.5, 30.0)),
            _ => Budget::evals(usize::MAX).with_proposal_cap(1 + rng.below(60)),
        };

        let mut sim_b =
            SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
        let mut batch = Tuning::new(&mut sim_b, budget);
        let mut sim_s =
            SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
        let mut scalar = Tuning::new(&mut sim_s, budget);

        for (bi, idxs) in batches.iter().enumerate() {
            let got: Vec<f64> = batch.eval_batch(idxs).to_vec();
            let mut want = Vec::new();
            for &i in idxs {
                if scalar.done() {
                    break;
                }
                want.push(scalar.eval(i));
            }
            assert_eq!(got.len(), want.len(), "case {case} batch {bi}");
            for (k, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "case {case} batch {bi} item {k}");
            }
            assert_eq!(batch.done(), scalar.done(), "case {case} batch {bi}");
            assert_eq!(
                batch.best_value().to_bits(),
                scalar.best_value().to_bits(),
                "case {case} batch {bi}"
            );
        }
        // A direct scalar eval after the batches sees identical state
        // (exercises the seen-bit rollback after truncated batches).
        if !batch.done() {
            let a = batch.eval(case % n);
            let b = scalar.eval(case % n);
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} post-eval");
        }
        assert_traces_bitwise_eq(
            &batch.finish(),
            &scalar.finish(),
            &format!("case {case}"),
        );
    }
}

/// Whole-run equivalence for the batched population optimizers: with the
/// same seed, a run whose batches take the gather fast path is bitwise
/// identical to one routed through the scalar per-eval fallback.
#[test]
fn prop_population_optimizer_batch_equals_scalar_fallback() {
    use std::sync::Arc;
    use tunetuner::runner::{Budget, SimulationRunner, Tuning};

    let mut rng = Rng::new(0x6A50);
    for case in 0..6u64 {
        let space = Arc::new(random_space(&mut rng));
        let cache = Arc::new(synthetic_cache(&space, &mut rng, 0.1));
        let budget = 10 + rng.below(50);
        for name in ["genetic_algorithm", "pso", "differential_evolution", "firefly"] {
            let run = |fallback: bool| {
                let mut sim = SimulationRunner::new_unchecked(
                    Arc::clone(&space),
                    Arc::clone(&cache),
                );
                let mut tuning = Tuning::new(&mut sim, Budget::evals(budget));
                tuning.set_scalar_batch_fallback(fallback);
                let opt = optimizers::create(name, &HyperParams::new()).unwrap();
                let mut orng = Rng::new(case * 97 + 13);
                opt.run(&mut tuning, &mut orng);
                tuning.finish()
            };
            let fast = run(false);
            let slow = run(true);
            assert_traces_bitwise_eq(&fast, &slow, &format!("case {case} {name}"));
        }
    }
}

/// The GA crossover operators preserve per-gene provenance: every child
/// gene comes from one of the two parents.
#[test]
fn prop_crossover_provenance() {
    use tunetuner::optimizers::ga::Crossover;
    let mut rng = Rng::new(0x90);
    for _ in 0..200 {
        let n = 2 + rng.below(10);
        let a: Vec<u16> = (0..n).map(|_| rng.below(8) as u16).collect();
        let b: Vec<u16> = (0..n).map(|_| rng.below(8) as u16).collect();
        for cx in [
            Crossover::SinglePoint,
            Crossover::TwoPoint,
            Crossover::Uniform,
            Crossover::DisruptiveUniform,
        ] {
            let (c1, c2) = cx.apply(&a, &b, &mut rng);
            for d in 0..n {
                assert!(c1[d] == a[d] || c1[d] == b[d]);
                assert!(c2[d] == a[d] || c2[d] == b[d]);
                // Gene conservation: {c1[d], c2[d]} == {a[d], b[d]}.
                let mut got = [c1[d], c2[d]];
                let mut want = [a[d], b[d]];
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want);
            }
        }
    }
}

/// The successive-halving schedule is well-formed for arbitrary inputs:
/// never empty, starts within the grid at the requested minimum repeats,
/// ends at full repeats, repeats strictly increase rung to rung (so a
/// survivor is never re-evaluated at fewer repeats than a previous rung)
/// while cohorts never grow, and the plan's cost fits the budget — with
/// the starting cohort maximal under it — unless even the minimal n0=1
/// ladder exceeds it.
#[test]
fn prop_halving_schedule_invariants() {
    use tunetuner::hypertuning::halving_schedule;
    let mut rng = Rng::new(0x4A1F);
    for case in 0..300u64 {
        let grid = 1 + rng.below(4000);
        let full = 1 + rng.below(32);
        let eta = 2 + rng.below(9);
        let min_r = 1 + rng.below(full);
        let budget = rng.range_f64(0.0, 2.0 * grid as f64);
        let s = halving_schedule(grid, full, budget, eta, min_r);
        let ctx = format!(
            "case {case}: grid={grid} full={full} eta={eta} min_r={min_r} \
             budget={budget:.3} -> {s:?}"
        );
        assert!(!s.is_empty(), "{ctx}");
        assert!(s[0].n >= 1 && s[0].n <= grid, "{ctx}");
        assert_eq!(s[0].repeats, min_r, "{ctx}");
        assert_eq!(s.last().unwrap().repeats, full, "{ctx}");
        for w in s.windows(2) {
            assert!(w[1].repeats > w[0].repeats, "{ctx}");
            assert!(w[1].repeats <= w[0].repeats * eta, "{ctx}");
            assert!(w[1].n <= w[0].n, "{ctx}");
        }
        // Reconstruct the cost of an arbitrary starting cohort on this
        // ladder (same integer-division shrinkage the planner uses).
        let cost_of = |n0: usize| -> f64 {
            s.iter()
                .enumerate()
                .map(|(i, r)| {
                    let mut n = n0;
                    for _ in 0..i {
                        n /= eta;
                    }
                    n.max(1) as f64 * r.repeats as f64 / full as f64
                })
                .sum()
        };
        let cost = cost_of(s[0].n);
        if s[0].n > 1 {
            assert!(cost <= budget + 1e-9, "{ctx}: cost {cost}");
        }
        // Maximality: a larger starting cohort would blow the budget.
        if s[0].n < grid {
            assert!(cost_of(s[0].n + 1) > budget + 1e-9, "{ctx}");
        }
    }
}

/// Fault isolation in the worker pool: for random job counts, worker
/// counts and faulty-job subsets, `scatter_result` always drains the
/// whole batch, reports a captured [`JobFailure`] in exactly the faulty
/// slots and an `Ok` in exactly the healthy ones — and the pool stays
/// usable for the next batch, faulted or clean.
#[test]
fn prop_executor_scatter_result_isolates_random_faults() {
    use std::sync::Arc;
    use tunetuner::campaign::Executor;

    let mut rng = Rng::new(0xFA17);
    for case in 0..25u64 {
        let workers = rng.below(5);
        let pool = Executor::new(workers);
        for round in 0..3u64 {
            let n_jobs = 1 + rng.below(24);
            let faulty: Arc<Vec<bool>> = Arc::new((0..n_jobs).map(|_| rng.chance(0.3)).collect());
            let jobs = Arc::clone(&faulty);
            let results = pool.scatter_result(n_jobs, move |i| {
                if jobs[i] {
                    panic!("boom {i}");
                }
                i * 10
            });
            let ctx = format!("case {case} round {round}: {n_jobs} jobs, {workers} workers");
            assert_eq!(results.len(), n_jobs, "{ctx}");
            for (i, r) in results.iter().enumerate() {
                match r {
                    Ok(v) => {
                        assert!(!faulty[i], "{ctx}: job {i} should have failed");
                        assert_eq!(*v, i * 10, "{ctx}");
                    }
                    Err(f) => {
                        assert!(faulty[i], "{ctx}: job {i} should have succeeded");
                        assert_eq!(f.job, i, "{ctx}");
                        assert!(
                            f.message.contains(&format!("boom {i}")),
                            "{ctx}: payload lost: {}",
                            f.message
                        );
                    }
                }
            }
        }
        // The pool survives any number of faulted batches: a clean
        // follow-up batch completes in full.
        let clean = pool.scatter_result(8, |i| i + 1);
        assert_eq!(clean.len(), 8);
        for (i, r) in clean.into_iter().enumerate() {
            assert_eq!(r.expect("clean batch must succeed"), i + 1, "case {case}");
        }
    }
}
