//! Whole-campaign equivalence and scale acceptance for constrained
//! compressed-rank search spaces (PR 7).
//!
//! * Same-seed simulated tuning campaigns must be bitwise identical —
//!   every trace point's config, value and clock — whichever rank index
//!   (bitset / map / compressed) serves the space and whether or not the
//!   flat decode buffer is materialized.
//! * The `#[ignore]`d acceptance test builds a >= 10^8-Cartesian-rank
//!   generated space (~1% valid), checks index roundtrips, and completes
//!   a full campaign under the default methodology budget. Run it with
//!   `cargo test --release --test constrained_space -- --ignored`.

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use std::sync::Arc;
use tunetuner::dataset::synth_cache;
use tunetuner::methodology::{evaluate_algorithm, SpaceEval};
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::runner::{Budget, SimulationRunner, Trace, Tuning};
use tunetuner::searchspace::{
    BuildOptions, ConstraintFamily, FlatPolicy, IndexKind, SearchSpace, SpaceGenSpec,
};
use tunetuner::util::rng::Rng;

/// One simulated campaign: synth cache over the space, fixed optimizer
/// seed, unique-eval budget.
fn campaign(space: Arc<SearchSpace>, algo: &str, seed: u64, evals: usize) -> Trace {
    let cache = Arc::new(synth_cache(&space, 11, 3, 0.05));
    let mut sim = SimulationRunner::new(Arc::clone(&space), cache).unwrap();
    let mut tuning = Tuning::new(&mut sim, Budget::evals(evals));
    let opt = optimizers::create(algo, &HyperParams::new()).unwrap();
    opt.run(&mut tuning, &mut Rng::new(seed));
    tuning.finish()
}

fn assert_traces_bitwise_eq(a: &Trace, b: &Trace, ctx: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{ctx}");
    assert_eq!(a.unique_evals, b.unique_evals, "{ctx}");
    assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits(), "{ctx}");
    for (p, (x, y)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(x.config, y.config, "{ctx} point {p}");
        assert_eq!(x.value.to_bits(), y.value.to_bits(), "{ctx} point {p}");
        assert_eq!(x.clock.to_bits(), y.clock.to_bits(), "{ctx} point {p}");
        assert_eq!(x.cached, y.cached, "{ctx} point {p}");
    }
}

#[test]
fn same_seed_campaigns_bitwise_identical_across_index_variants() {
    let spec = SpaceGenSpec::new(vec![24, 24, 16], 0.05, ConstraintFamily::Mixed, 7);
    // A spread of optimizer styles: population/batched, swarm, annealing
    // with CSR local search, and kick-based descent.
    for algo in ["genetic_algorithm", "pso", "dual_annealing", "basin_hopping"] {
        let mut reference: Option<Trace> = None;
        for index in [IndexKind::Bitset, IndexKind::Map, IndexKind::Compressed] {
            for flat in [FlatPolicy::Materialize, FlatPolicy::Elide] {
                let space = Arc::new(spec.build_with(BuildOptions { index, flat }).unwrap());
                assert_eq!(space.index_kind(), index);
                let trace = campaign(space, algo, 0xCA_FE, 60);
                match &reference {
                    None => reference = Some(trace),
                    Some(want) => assert_traces_bitwise_eq(
                        want,
                        &trace,
                        &format!("{algo} {index:?} {flat:?}"),
                    ),
                }
            }
        }
    }
}

/// PR 7 acceptance: a generated space past 10^8 Cartesian ranks at ~1%
/// validity builds, indexes, and completes a full simulated campaign
/// under the default methodology budget. Release-mode only (the debug
/// enumeration of 1.3e8 leaf evaluations is too slow for the tier-1 lane).
#[test]
#[ignore]
fn acceptance_1e8_cartesian_space_full_campaign() {
    let spec = SpaceGenSpec::new(vec![512, 512, 512], 0.01, ConstraintFamily::Hash, 7);
    let space = Arc::new(spec.build().unwrap());
    let cart = space.cartesian_size();
    assert!(cart >= 100_000_000, "cartesian size {cart}");
    assert_eq!(space.index_kind(), IndexKind::Compressed);
    let achieved = space.len() as f64 / cart as f64;
    assert!(
        (0.005..=0.02).contains(&achieved),
        "achieved validity {achieved} (len {})",
        space.len()
    );
    // Index roundtrips across the whole valid set (sampled).
    for i in (0..space.len()).step_by(1 + space.len() / 1000) {
        assert_eq!(space.index_of_rank(space.rank_of(i)), Some(i), "roundtrip {i}");
    }
    // Full campaign under the default methodology budget (SpaceEval's
    // baseline/budget derivation, one repeat).
    let cache = Arc::new(synth_cache(&space, 11, 1, 0.02));
    let se = SpaceEval::new(Arc::clone(&space), Arc::clone(&cache), 0.95, 50);
    let result =
        evaluate_algorithm("genetic_algorithm", &HyperParams::new(), &[se], 1, 7).unwrap();
    assert!(result.score.is_finite(), "score {}", result.score);
}
