//! Integration tests over the PJRT runtime + artifacts.
//!
//! These require `make artifacts` to have run (they are skipped with a
//! warning otherwise, so `cargo test` works in a fresh checkout).

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use tunetuner::gpu::specs::all_devices;
use tunetuner::kernels;
use tunetuner::perfmodel::analytical;
use tunetuner::perfmodel::contract::{INVALID_TIME, NUM_FEATURES};
use tunetuner::runtime::Engine;

fn pjrt_engine() -> Option<Engine> {
    let dir = Engine::default_artifacts_dir();
    match Engine::pjrt(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: no PJRT artifacts ({err:#}); run `make artifacts`");
            None
        }
    }
}

/// The HLO path (jax -> pallas -> HLO -> PJRT) must match the Rust oracle
/// to f32 round-off on every kernel/device pair.
#[test]
fn runtime_matches_oracle_everywhere() {
    let Some(engine) = pjrt_engine() else { return };
    for kernel in kernels::all_kernels().unwrap() {
        let feats: Vec<_> = (0..kernel.space().len())
            .step_by(7)
            .map(|i| kernel.features(i))
            .collect();
        for dev in all_devices() {
            let d = dev.to_vector();
            let got = engine.measure(&feats, &d).unwrap();
            for (f, m) in feats.iter().zip(&got) {
                let want = analytical::predict_time(f, &d);
                if want == INVALID_TIME {
                    assert_eq!(m.time, INVALID_TIME, "{}/{}", kernel.name, dev.name);
                } else {
                    let rel = ((m.time - want) / want).abs();
                    assert!(
                        rel < 1e-5,
                        "{}/{}: pjrt={} oracle={} rel={rel}",
                        kernel.name,
                        dev.name,
                        m.time,
                        want
                    );
                }
            }
        }
    }
}

/// Batch padding and chunking must not change results.
#[test]
fn runtime_chunking_invariance() {
    let Some(engine) = pjrt_engine() else { return };
    let kernel = kernels::kernel_by_name("synthetic").unwrap();
    let d = all_devices()[0].to_vector();
    let feats = kernel.all_features();
    let whole = engine.measure(&feats, &d).unwrap();
    // Odd-sized pieces exercise padding.
    let mut pieced = Vec::new();
    for chunk in feats.chunks(97) {
        pieced.extend(engine.measure(chunk, &d).unwrap());
    }
    assert_eq!(whole.len(), pieced.len());
    for (a, b) in whole.iter().zip(&pieced) {
        assert_eq!(a.time, b.time);
        assert_eq!(a.t_cold, b.t_cold);
        assert_eq!(a.t_hot, b.t_hot);
    }
}

/// measure_batch triple ordering holds through the HLO path.
#[test]
fn runtime_triple_ordering() {
    let Some(engine) = pjrt_engine() else { return };
    let kernel = kernels::kernel_by_name("gemm").unwrap();
    let d = all_devices()[2].to_vector();
    let feats: Vec<_> = (0..64).map(|i| kernel.features(i)).collect();
    for m in engine.measure(&feats, &d).unwrap() {
        if m.time != INVALID_TIME {
            assert!(m.t_cold >= m.time);
            assert!(m.t_hot <= m.time);
        }
    }
}

/// Degenerate all-zero features (padding rows) must be INVALID, not NaN.
#[test]
fn zero_rows_are_invalid_not_nan() {
    let Some(engine) = pjrt_engine() else { return };
    let d = all_devices()[0].to_vector();
    let feats = vec![[0f32; NUM_FEATURES]; 3];
    for m in engine.measure(&feats, &d).unwrap() {
        assert_eq!(m.time, INVALID_TIME);
    }
}
