//! Pipeline integration tests: hub -> simulation mode -> scoring ->
//! hypertuning, over the real kernels (native oracle engine so the tests
//! run without artifacts; the PJRT path is covered by integration.rs).

// Same style-lint policy as the library crate (see rust/src/lib.rs);
// integration tests and benches are separate crates and do not inherit it.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

use std::sync::Arc;
use tunetuner::dataset::hub::Hub;
use tunetuner::gpu::specs::A4000;
use tunetuner::hypertuning;
use tunetuner::kernels;
use tunetuner::methodology::{evaluate_algorithm, SpaceEval};
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::perfmodel::NoiseModel;
use tunetuner::runner::{Budget, LiveRunner, Runner, SimulationRunner, Tuning};
use tunetuner::runtime::Engine;
use tunetuner::util::rng::Rng;

fn tmp_hub(tag: &str) -> Hub {
    let dir = std::env::temp_dir().join(format!("tt_pipe_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    Hub::new(dir)
}

/// Build a small hub slice, tune through simulation mode, verify scoring.
#[test]
fn hub_to_scored_tuning() {
    let hub = tmp_hub("scored");
    let engine = Arc::new(Engine::native());
    hub.ensure(&["hotspot"], &["A4000", "W6600"], engine, 7).unwrap();

    let kernel = kernels::kernel_by_name("hotspot").unwrap();
    let mut spaces = Vec::new();
    for dev in ["A4000", "W6600"] {
        let cache = hub.load("hotspot", dev).unwrap();
        spaces.push(SpaceEval::new(kernel.space_arc(), cache, 0.95, 20));
    }
    // Random search calibrates to ~0 on real kernel spaces too.
    let rs = evaluate_algorithm("random_search", &HyperParams::new(), &spaces, 40, 3).unwrap();
    assert!(rs.score.abs() < 0.15, "rs score {}", rs.score);
    // And a tuned GA beats it.
    let hp = HyperParams::new()
        .set("method", "uniform")
        .set("popsize", 10i64)
        .set("mutation_chance", 10i64);
    let ga = evaluate_algorithm("genetic_algorithm", &hp, &spaces, 40, 3).unwrap();
    assert!(ga.score > rs.score, "ga {} rs {}", ga.score, rs.score);
    std::fs::remove_dir_all(hub.root()).ok();
}

/// The cache written to disk replays the live runner exactly (the paper's
/// "no perceivable difference" property, through the full file roundtrip).
#[test]
fn disk_roundtrip_is_exact() {
    let hub = tmp_hub("exact");
    let engine = Arc::new(Engine::native());
    hub.ensure(&["dedispersion"], &["A4000"], Arc::clone(&engine), 7).unwrap();
    let cache = hub.load("dedispersion", "A4000").unwrap();

    let kernel = kernels::kernel_by_name("dedispersion").unwrap();
    let mut live = LiveRunner::new(
        kernels::kernel_by_name("dedispersion").unwrap(),
        &A4000,
        engine,
        NoiseModel::default(),
        7, // the seed hub.ensure used
    );
    let mut sim = SimulationRunner::new(kernel.space_arc(), cache).unwrap();
    let mut rng = Rng::new(5);
    for _ in 0..20 {
        let idx = rng.below(kernel.space().len());
        let l = live.evaluate(idx);
        let s = sim.evaluate(idx);
        assert_eq!(l.value, s.value, "config {idx}");
        assert_eq!(l.observations, s.observations);
        assert_eq!(l.valid, s.valid);
        assert!((l.total_cost() - s.total_cost()).abs() < 1e-9);
    }
    std::fs::remove_dir_all(hub.root()).ok();
}

/// A miniature "tuning the tuner" campaign end-to-end: exhaustive DA
/// tuning on one space, then the meta replay cache drives a meta-strategy.
#[test]
fn mini_hypertuning_campaign() {
    let hub = tmp_hub("campaign");
    let engine = Arc::new(Engine::native());
    hub.ensure(&["convolution"], &["A4000"], engine, 7).unwrap();
    let kernel = kernels::kernel_by_name("convolution").unwrap();
    let cache = hub.load("convolution", "A4000").unwrap();
    let train = vec![SpaceEval::new(kernel.space_arc(), cache, 0.95, 15)];

    let hp_space = hypertuning::limited_space("dual_annealing").unwrap();
    let results =
        hypertuning::exhaustive_tuning("dual_annealing", &hp_space, "limited", &train, 3, 5)
            .unwrap();
    assert_eq!(results.results.len(), 8);
    assert!(results.best().score >= results.worst().score);

    // Meta replay: a random meta-strategy over the HP cache must find the
    // known-best HP config when allowed to exhaust the space.
    let meta_cache = hypertuning::meta_cache_from_results(&results, &hp_space).unwrap();
    let best_idx = meta_cache.optimum_index();
    assert_eq!(best_idx, results.best().config_idx);
    let mut sim =
        SimulationRunner::new_unchecked(Arc::new(hp_space), Arc::new(meta_cache));
    let mut tuning = Tuning::new(&mut sim, Budget::evals(8));
    let opt = optimizers::create("random_search", &HyperParams::new()).unwrap();
    opt.run(&mut tuning, &mut Rng::new(1));
    let trace = tuning.finish();
    assert_eq!(trace.unique_evals, 8);
    let found = trace.best().unwrap();
    assert!((found - (1.0 - results.best().score)).abs() < 1e-12);
    std::fs::remove_dir_all(hub.root()).ok();
}

/// Each device reorders the landscape: per kernel, the six devices must
/// not all share one optimal configuration (the property that makes
/// cross-device generalization a real test).
#[test]
fn devices_have_distinct_optima() {
    let hub = tmp_hub("optima");
    let engine = Arc::new(Engine::native());
    let devices = ["A100", "A4000", "A6000", "MI250X", "W6600", "W7800"];
    hub.ensure(&["gemm"], &devices, engine, 7).unwrap();
    let optima: std::collections::HashSet<usize> = devices
        .iter()
        .map(|d| hub.load("gemm", d).unwrap().optimum_index())
        .collect();
    assert!(optima.len() >= 3, "only {} distinct optima", optima.len());
    std::fs::remove_dir_all(hub.root()).ok();
}

/// Same seed reproduces the hub dataset bit-for-bit across builds.
#[test]
fn hub_seed_reproducibility() {
    let hub_a = tmp_hub("seed_a");
    let hub_b = tmp_hub("seed_b");
    let engine = Arc::new(Engine::native());
    hub_a.ensure(&["hotspot"], &["A4000"], Arc::clone(&engine), 11).unwrap();
    hub_b.ensure(&["hotspot"], &["A4000"], Arc::clone(&engine), 11).unwrap();
    let a = hub_a.load("hotspot", "A4000").unwrap();
    let b = hub_b.load("hotspot", "A4000").unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.value, rb.value);
        assert_eq!(ra.observations, rb.observations);
    }
    std::fs::remove_dir_all(hub_a.root()).ok();
    std::fs::remove_dir_all(hub_b.root()).ok();
}
