//! Integration tests for the invariant lint engine.
//!
//! The unit tests in `rust/src/analysis/` cover the lexer, the allow
//! grammar, and each rule against in-memory fixtures. This suite covers
//! the on-disk surface: walking a real fixture tree with `lint_tree`,
//! the persisted JSON envelope, and — most importantly — the golden
//! check that the repository's own `rust/src` is lint-clean, which is
//! the invariant CI enforces via `tunetuner lint --deny all`.

use std::path::{Path, PathBuf};

use tunetuner::analysis::report;
use tunetuner::analysis::{lint_source, lint_tree, DenySet, RuleId};
use tunetuner::util::fsio;
use tunetuner::util::json;

fn repo_src() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tt_lint_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// A fixture tree with one file per rule violation plus one clean file.
/// Returns the root. Written with `atomic_write` (which also creates
/// the parent directories).
fn write_fixture_tree(root: &Path) {
    let put = |rel: &str, src: &str| {
        fsio::atomic_write(&root.join(rel), src.as_bytes()).unwrap();
    };
    put(
        "w01_time.rs",
        "fn stamp() -> std::time::Instant { std::time::Instant::now() }\n",
    );
    put(
        "w01_map.rs",
        "use std::collections::HashMap;\npub fn f(m: &HashMap<u8, u8>) -> usize { m.len() }\n",
    );
    put(
        "sub/w02_write.rs",
        "fn save(p: &std::path::Path) { std::fs::write(p, b\"x\").ok(); }\n",
    );
    put(
        "sub/w03_unwrap.rs",
        "pub fn f(o: Option<u8>) -> u8 { o.unwrap() }\n",
    );
    put(
        "w04_partial.rs",
        "pub fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n",
    );
    put("w05_rng.rs", "pub fn f() { let r = Rng::new(42); }\n");
    put(
        "clean.rs",
        "pub fn add(a: u64, b: u64) -> u64 { a.wrapping_add(b) }\n",
    );
}

// ---------------------------------------------------------------------
// Golden test: the repository's own sources must be lint-clean.

#[test]
fn repo_is_lint_clean() {
    let report = lint_tree(&repo_src()).unwrap();
    assert!(report.files > 50, "walk found only {} files", report.files);
    let rendered = report::render_text(&report);
    assert!(
        report.diagnostics.is_empty(),
        "rust/src has lint violations:\n{rendered}"
    );
    // Every suppression in the tree carries a justified allow.
    assert!(report.allows >= report.suppressed);
}

// ---------------------------------------------------------------------
// Fixture tree through the real directory walk.

#[test]
fn fixture_tree_reports_every_rule() {
    let root = tmp_dir("tree");
    write_fixture_tree(&root);
    let report = lint_tree(&root).unwrap();
    assert_eq!(report.files, 7);

    let fired: Vec<RuleId> = report.diagnostics.iter().map(|d| d.rule).collect();
    for rule in [RuleId::W01, RuleId::W02, RuleId::W03, RuleId::W04, RuleId::W05] {
        assert!(fired.contains(&rule), "{rule:?} missing from {fired:?}");
    }
    // The clean file contributes nothing.
    assert!(report.diagnostics.iter().all(|d| !d.path.ends_with("clean.rs")));
    // Paths are /-normalized and relative to the root, spans exact.
    let w02 = report
        .diagnostics
        .iter()
        .find(|d| d.rule == RuleId::W02)
        .unwrap();
    assert_eq!(w02.path, "sub/w02_write.rs");
    assert_eq!(w02.line, 1);
    assert!(w02.col > 1);
    std::fs::remove_dir_all(&root).ok();
}

#[cfg(unix)]
#[test]
fn symlinks_are_skipped_not_followed() {
    let root = tmp_dir("sym");
    write_fixture_tree(&root);
    let outside = tmp_dir("sym_outside");
    fsio::atomic_write(&outside.join("evil.rs"), b"fn f() { panic!(\"x\"); }\n").unwrap();
    // A directory-symlink cycle must not recurse, and an out-of-tree
    // file symlink must not be linted as if in-tree.
    std::os::unix::fs::symlink(&root, root.join("cycle")).unwrap();
    std::os::unix::fs::symlink(outside.join("evil.rs"), root.join("evil_link.rs")).unwrap();
    let report = lint_tree(&root).unwrap();
    assert_eq!(report.files, 7, "symlinked entries must be skipped");
    assert!(report.diagnostics.iter().all(|d| !d.path.contains("evil")));
    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&outside).ok();
}

#[test]
fn fixture_tree_walk_is_deterministic() {
    let root = tmp_dir("det");
    write_fixture_tree(&root);
    let a = lint_tree(&root).unwrap();
    let b = lint_tree(&root).unwrap();
    assert_eq!(a.diagnostics, b.diagnostics);
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------
// Allow grammar end to end: suppression counted, malformed rejected.

#[test]
fn justified_allow_suppresses_and_is_counted() {
    let src = "pub fn f(o: Option<u8>) -> u8 {\n\
               // lint: allow(W03, reason = \"caller checks is_some first\")\n\
               o.unwrap()\n\
               }\n";
    let fl = lint_source("x/allowed.rs", src);
    assert!(fl.diagnostics.is_empty(), "{:?}", fl.diagnostics);
    assert_eq!((fl.suppressed, fl.allows), (1, 1));
}

#[test]
fn unjustified_allow_is_w00_and_never_deniable_off() {
    let src = "pub fn f(o: Option<u8>) -> u8 {\n\
               // lint: allow(W03)\n\
               o.unwrap()\n\
               }\n";
    let fl = lint_source("x/bad.rs", src);
    let rules: Vec<RuleId> = fl.diagnostics.iter().map(|d| d.rule).collect();
    assert!(rules.contains(&RuleId::W00), "{rules:?}");
    assert!(rules.contains(&RuleId::W03), "directive must not suppress");
    // Even `--deny none` still fails on malformed suppressions.
    let none = DenySet::parse("none").unwrap();
    assert!(none.denies(RuleId::W00));
    assert!(!none.denies(RuleId::W03));
}

#[test]
fn deny_list_selects_rules() {
    let some = DenySet::parse("W01,W03").unwrap();
    assert!(some.denies(RuleId::W01));
    assert!(some.denies(RuleId::W03));
    assert!(!some.denies(RuleId::W04));
    assert!(DenySet::parse("all").unwrap().denies(RuleId::W05));
    assert!(DenySet::parse("W06").is_err());
    assert!(DenySet::parse("W00").is_err(), "W00 is implicit, not optable");
}

// ---------------------------------------------------------------------
// Envelope: versioned schema, saved atomically, round-trips.

#[test]
fn envelope_saves_and_round_trips() {
    let root = tmp_dir("env");
    write_fixture_tree(&root);
    let report = lint_tree(&root).unwrap();
    let out = root.join("report/lint.json");
    report::save(&report, &out).unwrap();

    let body = std::fs::read_to_string(&out).unwrap();
    assert!(body.ends_with('\n'));
    let j = json::parse(&body).unwrap();
    assert_eq!(j.at(&["schema"]).and_then(|v| v.as_str()), Some("tunetuner-lint"));
    assert_eq!(j.at(&["schema_version"]).and_then(|v| v.as_usize()), Some(1));
    assert_eq!(j.at(&["files"]).and_then(|v| v.as_usize()), Some(7));
    let n = j.at(&["violations"]).and_then(|v| v.as_usize()).unwrap();
    let diags = j.at(&["diagnostics"]).and_then(|v| v.as_arr()).unwrap();
    assert_eq!(diags.len(), n);
    assert!(n >= 5, "expected at least one violation per rule, got {n}");
    // Counts sum to the violation total.
    let counts = j.at(&["counts"]).and_then(|v| v.as_obj()).unwrap();
    let sum: usize = counts.values().filter_map(|v| v.as_usize()).sum();
    assert_eq!(sum, n);
    // No stray staging debris from the atomic write.
    let dir = out.parent().unwrap();
    let stray = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name() != "lint.json")
        .count();
    assert_eq!(stray, 0);
    std::fs::remove_dir_all(&root).ok();
}
