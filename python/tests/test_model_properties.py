"""Model-level properties of the device performance model (L2).

These pin down the *behavioural* contract the Rust coordinator relies on:
roofline monotonicity, device scaling, ruggedness bounds, and the shape of
the measure_batch outputs.
"""

import numpy as np
import pytest

from compile import contract, model
from compile.kernels import ref

from .conftest import make_device, make_features


def _set(f, col, val):
    g = f.copy()
    g[:, col] = val
    return g


def test_measure_batch_outputs(features256, device):
    times, t_cold, t_hot = model.measure_batch(features256, device)
    times, t_cold, t_hot = map(np.asarray, (times, t_cold, t_hot))
    valid = times != contract.INVALID_TIME
    assert valid.any()
    # cold >= true >= hot on the valid set
    assert np.all(t_cold[valid] >= times[valid])
    assert np.all(t_hot[valid] <= times[valid])
    # warmup drift bounded to [2%, 6%]
    drift = t_cold[valid] / times[valid]
    assert np.all(drift >= 1.02 - 1e-6) and np.all(drift <= 1.06 + 1e-6)


def test_more_flops_never_faster(device):
    f = make_features(256, seed=11)
    lo = np.asarray(ref.predict_times(_set(f, contract.F_FLOPS, 1e10), device))
    hi = np.asarray(ref.predict_times(_set(f, contract.F_FLOPS, 2e10), device))
    valid = lo != contract.INVALID_TIME
    assert np.all(hi[valid] >= lo[valid] - 1e-12)


def test_more_bytes_never_faster(device):
    f = make_features(256, seed=12)
    lo = np.asarray(ref.predict_times(_set(f, contract.F_BYTES, 1e9), device))
    hi = np.asarray(ref.predict_times(_set(f, contract.F_BYTES, 4e9), device))
    valid = lo != contract.INVALID_TIME
    assert np.all(hi[valid] >= lo[valid] - 1e-12)


def test_bandwidth_scaling_helps_memory_bound(device):
    f = make_features(256, seed=13)
    f = _set(f, contract.F_FLOPS, 1e8)   # negligible compute
    f = _set(f, contract.F_BYTES, 1e10)  # heavy traffic -> memory bound
    d2 = device.copy()
    d2[contract.D_BW_GBS] *= 2
    base = np.asarray(ref.predict_times(f, device))
    fast = np.asarray(ref.predict_times(f, d2))
    valid = base != contract.INVALID_TIME
    assert np.all(fast[valid] < base[valid])


def test_peak_scaling_helps_compute_bound(device):
    f = make_features(256, seed=14)
    f = _set(f, contract.F_FLOPS, 1e12)
    f = _set(f, contract.F_BYTES, 1e7)
    d2 = device.copy()
    d2[contract.D_PEAK_GFLOPS] *= 2
    base = np.asarray(ref.predict_times(f, device))
    fast = np.asarray(ref.predict_times(f, d2))
    valid = base != contract.INVALID_TIME
    assert np.all(fast[valid] < base[valid])


def test_ruggedness_bounded(device):
    """Rugged factor must stay within 1 +- rug_amp of the smooth model."""
    f = make_features(512, seed=15)
    smooth_dev = device.copy()
    smooth_dev[contract.D_RUG_AMP] = 0.0
    rough = np.asarray(ref.predict_times(f, device))
    smooth = np.asarray(ref.predict_times(f, smooth_dev))
    valid = smooth != contract.INVALID_TIME
    amp = device[contract.D_RUG_AMP]
    # subtract the launch-overhead floor before comparing ratios
    ratio = rough[valid] / smooth[valid]
    assert np.all(ratio <= 1 + amp + 0.05)
    assert np.all(ratio >= 1 - amp - 0.05)


def test_device_dependent_landscapes():
    """Different rug_seed must reorder configs (device-specific optima)."""
    f = make_features(1024, seed=16)
    d1 = make_device(seed=1)
    d2 = d1.copy()
    d2[contract.D_RUG_SEED] = (d1[contract.D_RUG_SEED] + 0.5) % 1.0
    t1 = np.asarray(ref.predict_times(f, d1))
    t2 = np.asarray(ref.predict_times(f, d2))
    valid = t1 != contract.INVALID_TIME
    r1 = np.argsort(t1[valid])
    r2 = np.argsort(t2[valid])
    assert not np.array_equal(r1, r2)


def test_occupancy_affects_time(device):
    """Squeezing occupancy via smem must not speed things up."""
    f = make_features(256, seed=17)
    lo = np.asarray(ref.predict_times(_set(f, contract.F_SMEM, 1024), device))
    hi = np.asarray(ref.predict_times(_set(f, contract.F_SMEM, 49152), device))
    both = (lo != contract.INVALID_TIME) & (hi != contract.INVALID_TIME)
    assert both.any()
    assert np.mean(hi[both] >= lo[both] - 1e-12) > 0.95


def test_lowering_shapes():
    lowered = model.lower_measure_batch(256)
    text = lowered.as_text()
    assert "256" in text


@pytest.fixture
def features256():
    return make_features(256, seed=42)


@pytest.fixture
def device():
    return make_device(seed=3)
