"""Shared fixtures: realistic random feature batches and device vectors."""

import numpy as np
import pytest

from compile import contract


def make_features(n, seed=0):
    """Random but realistic configuration feature matrix f32[n, F]."""
    rng = np.random.default_rng(seed)
    f = np.zeros((n, contract.NUM_FEATURES), dtype=np.float32)
    f[:, contract.F_FLOPS] = rng.uniform(1e8, 5e11, n)
    f[:, contract.F_BYTES] = rng.uniform(1e7, 5e10, n)
    f[:, contract.F_TPB] = rng.choice([32, 64, 96, 128, 256, 512, 1024], n)
    f[:, contract.F_REGS] = rng.integers(16, 128, n)
    f[:, contract.F_SMEM] = rng.choice([0, 1024, 4096, 16384, 49152], n)
    f[:, contract.F_BLOCKS] = rng.integers(8, 65536, n)
    f[:, contract.F_VECW] = rng.choice([1, 2, 4, 8], n)
    f[:, contract.F_UNROLL] = rng.choice([1, 2, 4, 8, 16], n)
    f[:, contract.F_COAL] = rng.uniform(0, 1, n)
    f[:, contract.F_CACHE] = rng.uniform(0, 1, n)
    f[:, contract.F_HASH_A] = rng.uniform(0, 1, n)
    f[:, contract.F_HASH_B] = rng.uniform(0, 1, n)
    return f


def make_device(seed=0):
    """A plausible GPU device vector f32[G]."""
    rng = np.random.default_rng(seed + 1000)
    d = np.zeros(contract.NUM_DEVICE, dtype=np.float32)
    d[contract.D_NUM_SM] = rng.choice([28, 48, 84, 108, 110])
    d[contract.D_PEAK_GFLOPS] = rng.uniform(5000, 40000)
    d[contract.D_BW_GBS] = rng.uniform(200, 2000)
    d[contract.D_MAX_THREADS] = rng.choice([1024, 1536, 2048])
    d[contract.D_SMEM_SM] = rng.choice([65536, 102400, 167936])
    d[contract.D_REGS_SM] = 65536
    d[contract.D_MAX_BLOCKS] = rng.choice([16, 24, 32])
    d[contract.D_WARP] = rng.choice([32, 64])
    d[contract.D_RUG_SEED] = rng.uniform(0, 1)
    d[contract.D_RUG_AMP] = 0.25
    return d


@pytest.fixture
def features256():
    return make_features(256, seed=42)


@pytest.fixture
def device():
    return make_device(seed=3)
