"""AOT pipeline tests: lowering, HLO text emission, contract metadata."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, contract, model

from .conftest import make_device, make_features


def test_contract_json_is_valid_and_complete():
    doc = json.loads(aot.contract_json())
    assert doc["version"] == contract.CONTRACT_VERSION
    assert doc["num_features"] == contract.NUM_FEATURES
    assert doc["num_device"] == contract.NUM_DEVICE
    assert doc["outputs"] == ["times", "t_cold", "t_hot"]
    idx = doc["indices"]
    # Every F_*/D_* constant must be present with the right value.
    for name, val in vars(contract).items():
        if name.startswith(("F_", "D_")) and isinstance(val, int):
            assert idx[name] == val, name
    # Indices must be a proper permutation of their ranges.
    fs = sorted(v for k, v in idx.items() if k.startswith("F_"))
    ds = sorted(v for k, v in idx.items() if k.startswith("D_"))
    assert fs == list(range(contract.NUM_FEATURES))
    assert ds == list(range(contract.NUM_DEVICE))


@pytest.mark.parametrize("n", [256, 1024])
def test_hlo_text_emission(n):
    lowered = model.lower_measure_batch(n)
    text = aot.to_hlo_text(lowered)
    # HLO text module with the expected entry shapes.
    assert text.startswith("HloModule")
    assert f"f32[{n},{contract.NUM_FEATURES}]" in text
    assert f"f32[{contract.NUM_DEVICE}]" in text
    # Three f32[n] outputs in a tuple.
    assert text.count(f"f32[{n}]") >= 3
    # No TPU custom-calls: the artifact must run on the CPU PJRT client.
    assert "custom-call" not in text.lower() or "Mosaic" not in text


def test_lowered_module_executes_like_eager():
    n = 256
    f = make_features(n, seed=1)
    d = make_device(seed=1)
    eager = model.measure_batch(f, d)
    compiled = model.lower_measure_batch(n).compile()
    aot_out = compiled(jnp.asarray(f), jnp.asarray(d))
    for e, a in zip(eager, aot_out):
        np.testing.assert_allclose(np.asarray(e), np.asarray(a), rtol=1e-6)


def test_batch_sizes_cover_config():
    # Every advertised batch size must lower cleanly.
    for n in contract.BATCH_SIZES:
        assert n % contract.BLOCK_N == 0, "artifact batches must tile evenly"
