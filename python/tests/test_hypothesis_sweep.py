"""Hypothesis sweeps: pallas kernel vs jnp oracle over generated inputs.

Sweeps shapes and feature ranges beyond the 'realistic' fixture ranges to
catch tile-boundary and degenerate-value bugs in the BlockSpec plumbing.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from compile import contract
from compile.kernels import perfmodel, ref

def _f32(x):
    """Round a bound to an exactly f32-representable value (hypothesis requires it)."""
    return float(np.float32(x))


FEATURE_RANGES = [
    (1e6, 1e13),   # flops
    (1e5, 1e12),   # bytes
    (1.0, 2048.0),  # tpb (includes invalid > 1024)
    (1.0, 256.0),  # regs
    (0.0, 2e5),    # smem
    (1.0, 1e6),    # blocks
    (1.0, 8.0),    # vecw
    (1.0, 16.0),   # unroll
    (0.0, 1.0),    # coal
    (0.0, 1.0),    # cache
    (0.0, 1.0),    # hash_a
    (0.0, 1.0),    # hash_b
]

DEVICE_RANGES = [
    (1.0, 256.0),      # num_sm
    (100.0, 1e5),      # peak gflops
    (10.0, 4000.0),    # bw
    (256.0, 4096.0),   # max threads / sm
    (1e4, 3e5),        # smem / sm
    (1e4, 3e5),        # regs / sm
    (1.0, 64.0),       # max blocks
    (32.0, 64.0),      # warp
    (0.0, 1.0),        # rug seed
    (0.0, 0.5),        # rug amp
]


@st.composite
def feature_batches(draw):
    n_tiles = draw(st.integers(min_value=1, max_value=6))
    n = n_tiles * contract.BLOCK_N
    cols = []
    for lo, hi in FEATURE_RANGES:
        cols.append(
            draw(
                hnp.arrays(
                    np.float32,
                    (n,),
                    elements=st.floats(
                        _f32(lo),
                        _f32(hi),
                        allow_nan=False,
                        allow_infinity=False,
                        width=32,
                    ),
                )
            )
        )
    return np.stack(cols, axis=1)


@st.composite
def devices(draw):
    vals = []
    for lo, hi in DEVICE_RANGES:
        vals.append(
            draw(
                st.floats(
                    _f32(lo), _f32(hi), allow_nan=False, allow_infinity=False, width=32
                )
            )
        )
    return np.asarray(vals, dtype=np.float32)


@settings(max_examples=25, deadline=None)
@given(f=feature_batches(), d=devices())
def test_pallas_matches_ref_everywhere(f, d):
    got = np.asarray(perfmodel.predict_times(f, d))
    want = np.asarray(ref.predict_times(f, d))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(f=feature_batches(), d=devices())
def test_output_domain(f, d):
    """Every output is either the invalid sentinel or a positive finite time."""
    got = np.asarray(perfmodel.predict_times(f, d))
    sentinel = got == contract.INVALID_TIME
    assert np.all(np.isfinite(got))
    assert np.all(got[~sentinel] > 0)


@settings(max_examples=15, deadline=None)
@given(f=feature_batches(), d=devices())
def test_permutation_equivariance(f, d):
    """The model is elementwise over configs: permuting rows permutes outputs."""
    perm = np.random.default_rng(0).permutation(f.shape[0])
    a = np.asarray(perfmodel.predict_times(f, d))
    b = np.asarray(perfmodel.predict_times(f[perm], d))
    np.testing.assert_allclose(b, a[perm], rtol=1e-6)
