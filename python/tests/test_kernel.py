"""Pallas kernel vs pure-jnp oracle: the core L1 correctness signal."""

import numpy as np
import pytest

from compile import contract
from compile.kernels import perfmodel, ref

from .conftest import make_device, make_features


@pytest.mark.parametrize("n", [256, 512, 1024, 4096])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pallas_matches_ref(n, seed):
    f = make_features(n, seed=seed)
    d = make_device(seed=seed)
    got = np.asarray(perfmodel.predict_times(f, d))
    want = np.asarray(ref.predict_times(f, d))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("block_n", [64, 128, 256])
def test_block_size_invariance(block_n):
    """The BlockSpec tiling must not change the numerics."""
    f = make_features(512, seed=7)
    d = make_device(seed=7)
    base = np.asarray(perfmodel.predict_times(f, d, block_n=256))
    got = np.asarray(perfmodel.predict_times(f, d, block_n=block_n))
    np.testing.assert_allclose(got, base, rtol=1e-6)


def test_rejects_misaligned_batch():
    f = make_features(300, seed=0)
    d = make_device(seed=0)
    with pytest.raises(ValueError, match="multiple of block_n"):
        perfmodel.predict_times(f, d)


def test_rejects_wrong_feature_count():
    f = make_features(256, seed=0)[:, :-1]
    d = make_device(seed=0)
    with pytest.raises(ValueError, match="features"):
        perfmodel.predict_times(f, d)


def test_invalid_configs_get_sentinel(device):
    f = make_features(256, seed=5)
    # threads-per-block over the hardware limit -> launch failure
    f[:8, contract.F_TPB] = 2048
    # smem over any per-SM budget -> zero resident blocks
    f[8:16, contract.F_SMEM] = 1e9
    got = np.asarray(perfmodel.predict_times(f, device))
    assert np.all(got[:16] == contract.INVALID_TIME)


def test_warp_divisibility(device):
    f = make_features(256, seed=6)
    warp = device[contract.D_WARP]
    f[:, contract.F_TPB] = warp * 3 + 1  # not warp-divisible
    got = np.asarray(perfmodel.predict_times(f, device))
    assert np.all(got == contract.INVALID_TIME)


def test_valid_configs_finite_positive(features256, device):
    got = np.asarray(perfmodel.predict_times(features256, device))
    valid = got != contract.INVALID_TIME
    assert valid.sum() > 0
    assert np.all(got[valid] > 0)
    assert np.all(np.isfinite(got[valid]))


def test_deterministic(features256, device):
    a = np.asarray(perfmodel.predict_times(features256, device))
    b = np.asarray(perfmodel.predict_times(features256, device))
    np.testing.assert_array_equal(a, b)
