"""AOT: lower the L2 graph to HLO text artifacts for the Rust runtime.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per batch size in contract.BATCH_SIZES:
    artifacts/perfmodel_b{N}.hlo.txt
plus artifacts/contract.json describing the vector layout, so the Rust
runtime can validate at load time that it agrees with the contract the
artifacts were built against.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly.
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import contract, model


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def contract_json() -> str:
    names = {
        k: v
        for k, v in vars(contract).items()
        if k.startswith(("F_", "D_")) and isinstance(v, int)
    }
    payload = {
        "version": contract.CONTRACT_VERSION,
        "num_features": contract.NUM_FEATURES,
        "num_device": contract.NUM_DEVICE,
        "invalid_time": contract.INVALID_TIME,
        "launch_overhead": contract.LAUNCH_OVERHEAD,
        "max_tpb": contract.MAX_TPB,
        "block_n": contract.BLOCK_N,
        "batch_sizes": list(contract.BATCH_SIZES),
        "outputs": ["times", "t_cold", "t_hot"],
        "indices": names,
    }
    return json.dumps(payload, indent=2, sort_keys=True)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--batch-sizes",
        default=",".join(str(b) for b in contract.BATCH_SIZES),
        help="comma-separated batch sizes to lower",
    )
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    sizes = [int(s) for s in args.batch_sizes.split(",") if s]
    for n in sizes:
        lowered = model.lower_measure_batch(n)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"perfmodel_b{n}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    cpath = os.path.join(args.out_dir, "contract.json")
    with open(cpath, "w") as fh:
        fh.write(contract_json() + "\n")
    print(f"wrote {cpath}")


if __name__ == "__main__":
    main()
