"""L2: the JAX compute graph around the L1 device-model kernel.

The graph evaluated on the Rust hot path is `measure_batch`: the device
model (Pallas, L1) plus the summary statistics the auto-tuner records per
configuration.  Keeping the statistics inside the lowered module means the
Rust side gets (mean, min, max) of the simulated repeated observations in
one PJRT execution instead of post-processing on the coordinator thread.

Noise is intentionally NOT part of the lowered module: observation noise
must be reproducible per (space, config, repeat) from the Rust side's
seeded RNG, so L3 owns it.  What the module adds on top of the raw kernel
is the deterministic per-observation *systematic* spread (warmup drift),
which is a pure function of the inputs.
"""

import jax
import jax.numpy as jnp

from .contract import NUM_DEVICE, NUM_FEATURES
from .kernels import perfmodel


def predict_batch(features, device):
    """Bare device-model times: f32[N, F], f32[G] -> f32[N]."""
    return perfmodel.predict_times(features, device)


def measure_batch(features, device):
    """Device model + the per-config summary the tuner records.

    Returns a 3-tuple of f32[N]:
      times  -- predicted 'true' kernel time per configuration
      t_cold -- first-observation (cold/warmup) time: times * warmup drift
      t_hot  -- steady-state best-case time: times * hot-cache factor

    The cold/hot pair bounds the systematic part of the 32-observation
    spread; L3 draws the stochastic part around it.
    """
    times = perfmodel.predict_times(features, device)
    # Warmup drift: cold first run is 2-6% slower depending on the config
    # hash (instruction-cache and clock-ramp effects are config-dependent).
    drift = 1.02 + 0.04 * features[:, -1]
    t_cold = times * drift
    t_hot = times * 0.995
    return times, t_cold, t_hot


def lower_measure_batch(batch_size):
    """Lower measure_batch for a fixed batch size; returns the jax Lowered."""
    fspec = jax.ShapeDtypeStruct((batch_size, NUM_FEATURES), jnp.float32)
    dspec = jax.ShapeDtypeStruct((NUM_DEVICE,), jnp.float32)
    return jax.jit(measure_batch).lower(fspec, dspec)
