"""The L1/L2 <-> L3 interchange contract.

This module is the single source of truth for the layout of the feature
and device vectors the batched device performance model consumes.  The
Rust side (rust/src/perfmodel/contract.rs) mirrors these constants; aot.py
additionally emits `artifacts/contract.json` so the Rust runtime can
verify at load time that the artifacts were built against the layout it
expects.

All quantities are f32.  Times are in seconds.
"""

# ---- feature vector (per kernel configuration) ------------------------------
F_FLOPS = 0        # total floating point operations for the problem size
F_BYTES = 1        # total DRAM bytes moved (read + write)
F_TPB = 2          # threads per block
F_REGS = 3         # registers per thread
F_SMEM = 4         # shared memory per block (bytes)
F_BLOCKS = 5       # grid size in blocks
F_VECW = 6         # vector width (1, 2, 4, 8)
F_UNROLL = 7       # unroll factor (1..16)
F_COAL = 8         # memory coalescing quality in [0, 1]
F_CACHE = 9        # cache-hint quality in [0, 1]
F_HASH_A = 10      # per-config hash in [0, 1) (landscape ruggedness)
F_HASH_B = 11      # second independent hash in [0, 1)
NUM_FEATURES = 12

# ---- device vector (per simulated GPU) ---------------------------------------
D_NUM_SM = 0       # number of SMs / CUs
D_PEAK_GFLOPS = 1  # peak fp32 GFLOP/s
D_BW_GBS = 2       # peak DRAM bandwidth GB/s
D_MAX_THREADS = 3  # max resident threads per SM
D_SMEM_SM = 4      # shared memory per SM (bytes)
D_REGS_SM = 5      # registers per SM
D_MAX_BLOCKS = 6   # max resident blocks per SM
D_WARP = 7         # warp / wavefront size (32 or 64)
D_RUG_SEED = 8     # device ruggedness blend seed in [0, 1)
D_RUG_AMP = 9      # ruggedness amplitude (e.g. 0.25)
NUM_DEVICE = 10

# ---- model constants ----------------------------------------------------------
INVALID_TIME = 1.0e9   # sentinel for configurations that fail to launch
LAUNCH_OVERHEAD = 3.0e-6  # fixed per-wave launch overhead in seconds
MAX_TPB = 1024.0       # hardware limit on threads per block

# ---- AOT artifact batch sizes --------------------------------------------------
BLOCK_N = 256                       # pallas tile along the config axis
BATCH_SIZES = (256, 1024, 4096, 16384)  # one HLO artifact per size

CONTRACT_VERSION = 1
