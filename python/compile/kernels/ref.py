"""Pure-jnp oracle for the batched GPU performance model.

This is the correctness reference for the Pallas kernel in perfmodel.py;
pytest asserts the two agree to tight tolerance.  The Rust analytical
model (rust/src/perfmodel/analytical.rs) implements the same arithmetic
in f32 and is cross-checked against the AOT HLO artifacts at test time.

The model combines the classic GPU occupancy calculation with a roofline
time estimate, wave quantization, and a deterministic multiplicative
"ruggedness" term derived from per-config hash features, producing
discrete, constrained, rugged, device-dependent landscapes -- the search
space properties that the paper's hyperparameter-tuning method relies on.
"""

import jax.numpy as jnp

from ..contract import (
    D_BW_GBS,
    D_MAX_BLOCKS,
    D_MAX_THREADS,
    D_NUM_SM,
    D_PEAK_GFLOPS,
    D_REGS_SM,
    D_RUG_AMP,
    D_RUG_SEED,
    D_SMEM_SM,
    D_WARP,
    F_BLOCKS,
    F_BYTES,
    F_CACHE,
    F_COAL,
    F_FLOPS,
    F_HASH_A,
    F_HASH_B,
    F_REGS,
    F_SMEM,
    F_TPB,
    F_UNROLL,
    F_VECW,
    INVALID_TIME,
    LAUNCH_OVERHEAD,
    MAX_TPB,
)


def predict_times(features, device):
    """Predicted kernel execution time per configuration.

    Args:
      features: f32[N, NUM_FEATURES] per-configuration resource usage.
      device:   f32[NUM_DEVICE] simulated GPU parameters.

    Returns:
      f32[N] predicted times in seconds; INVALID_TIME where the
      configuration cannot launch on the device.
    """
    f = features.astype(jnp.float32)
    d = device.astype(jnp.float32)

    flops = f[:, F_FLOPS]
    bytes_rw = f[:, F_BYTES]
    tpb = f[:, F_TPB]
    regs = f[:, F_REGS]
    smem = f[:, F_SMEM]
    blocks = f[:, F_BLOCKS]
    vecw = f[:, F_VECW]
    unroll = f[:, F_UNROLL]
    coal = f[:, F_COAL]
    cache = f[:, F_CACHE]
    hash_a = f[:, F_HASH_A]
    hash_b = f[:, F_HASH_B]

    num_sm = d[D_NUM_SM]
    peak = d[D_PEAK_GFLOPS] * 1.0e9
    bandwidth = d[D_BW_GBS] * 1.0e9
    max_threads = d[D_MAX_THREADS]
    smem_sm = d[D_SMEM_SM]
    regs_sm = d[D_REGS_SM]
    max_blocks = d[D_MAX_BLOCKS]
    warp = d[D_WARP]
    rug_seed = d[D_RUG_SEED]
    rug_amp = d[D_RUG_AMP]

    # --- occupancy: resident blocks per SM, limited by each resource --------
    occ_threads = jnp.floor(max_threads / jnp.maximum(tpb, 1.0))
    occ_smem = jnp.floor(smem_sm / jnp.maximum(smem, 1.0))
    occ_regs = jnp.floor(regs_sm / jnp.maximum(regs * tpb, 1.0))
    occ_blocks = jnp.minimum(
        jnp.minimum(occ_threads, occ_smem), jnp.minimum(occ_regs, max_blocks)
    )

    # --- launch validity -----------------------------------------------------
    warp_ok = jnp.floor(tpb / warp) * warp == tpb
    valid = (occ_blocks >= 1.0) & (tpb <= MAX_TPB) & (tpb >= warp) & warp_ok

    occupancy = jnp.minimum(occ_blocks * tpb / max_threads, 1.0)

    # --- efficiency curves -----------------------------------------------------
    # Vector width: sweet spot around 2-4 lanes; log2(vecw) in {0,1,2,3}.
    vec_bonus = 1.0 - 0.08 * jnp.abs(jnp.log2(jnp.maximum(vecw, 1.0)) - 1.5)
    # Unrolling: diminishing returns past ~4x.
    unroll_curve = 1.0 - 0.05 * jnp.abs(jnp.log2(jnp.maximum(unroll, 1.0)) - 2.0)
    eff_compute = jnp.clip(
        (0.45 + 0.55 * occupancy) * vec_bonus * unroll_curve, 0.05, 1.0
    )
    eff_memory = jnp.clip(
        (0.55 + 0.45 * jnp.sqrt(occupancy))
        * (0.6 + 0.4 * coal)
        * (1.0 + 0.15 * cache),
        0.05,
        1.05,
    )

    # --- roofline ----------------------------------------------------------------
    t_compute = flops / (peak * eff_compute)
    t_memory = bytes_rw / (bandwidth * eff_memory)

    # --- wave quantization ----------------------------------------------------
    resident = jnp.maximum(occ_blocks * num_sm, 1.0)
    waves = jnp.ceil(blocks / resident)
    wave_penalty = waves * resident / jnp.maximum(blocks, 1.0)

    # --- deterministic ruggedness ----------------------------------------------
    # A device-seeded blend of two decorrelated per-config hashes: purely
    # mul/add so Rust f32 and XLA f32 agree to ~1 ulp (no fract-of-large
    # products that would amplify rounding differences).
    u = hash_a * (1.0 - rug_seed) + hash_b * rug_seed
    rugged = 1.0 + rug_amp * (2.0 * u - 1.0)

    t = (
        jnp.maximum(t_compute, t_memory) * wave_penalty * rugged
        + LAUNCH_OVERHEAD * waves
    )
    return jnp.where(valid, t, INVALID_TIME).astype(jnp.float32)
