"""L1: the batched GPU device model as a Pallas kernel.

Every live "measurement" of a kernel configuration in the Rust coordinator
funnels through this kernel: the L3 brute-forcer and live runner pack
configuration feature matrices, the AOT-compiled HLO (which this kernel
lowers into) evaluates the device model for a whole batch at once.

Tiling: the configuration axis N is streamed through VMEM in
(BLOCK_N, NUM_FEATURES) tiles via BlockSpec; the device vector is
broadcast to every tile.  The model is elementwise over N (VPU work, no
MXU); VMEM footprint per tile is BLOCK_N * (NUM_FEATURES + 1) * 4 bytes
plus the (1, NUM_DEVICE) device row -- ~13 KiB at BLOCK_N=256, far below
the ~16 MiB VMEM budget, leaving headroom for double buffering.

interpret=True is mandatory here: the artifacts must execute on the CPU
PJRT client in the Rust runtime, and a real TPU lowering would emit a
Mosaic custom-call the CPU plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..contract import (
    BLOCK_N,
    D_BW_GBS,
    D_MAX_BLOCKS,
    D_MAX_THREADS,
    D_NUM_SM,
    D_PEAK_GFLOPS,
    D_REGS_SM,
    D_RUG_AMP,
    D_RUG_SEED,
    D_SMEM_SM,
    D_WARP,
    F_BLOCKS,
    F_BYTES,
    F_CACHE,
    F_COAL,
    F_FLOPS,
    F_HASH_A,
    F_HASH_B,
    F_REGS,
    F_SMEM,
    F_TPB,
    F_UNROLL,
    F_VECW,
    INVALID_TIME,
    LAUNCH_OVERHEAD,
    MAX_TPB,
    NUM_DEVICE,
    NUM_FEATURES,
)


def _perfmodel_kernel(f_ref, d_ref, o_ref):
    """One (BLOCK_N, NUM_FEATURES) tile of the device model.

    The arithmetic must stay in lockstep with kernels/ref.py (the jnp
    oracle) and rust/src/perfmodel/analytical.rs (the Rust oracle); all
    three are cross-checked by tests.
    """
    f = f_ref[...]
    d = d_ref[...]

    flops = f[:, F_FLOPS]
    bytes_rw = f[:, F_BYTES]
    tpb = f[:, F_TPB]
    regs = f[:, F_REGS]
    smem = f[:, F_SMEM]
    blocks = f[:, F_BLOCKS]
    vecw = f[:, F_VECW]
    unroll = f[:, F_UNROLL]
    coal = f[:, F_COAL]
    cache = f[:, F_CACHE]
    hash_a = f[:, F_HASH_A]
    hash_b = f[:, F_HASH_B]

    num_sm = d[0, D_NUM_SM]
    peak = d[0, D_PEAK_GFLOPS] * 1.0e9
    bandwidth = d[0, D_BW_GBS] * 1.0e9
    max_threads = d[0, D_MAX_THREADS]
    smem_sm = d[0, D_SMEM_SM]
    regs_sm = d[0, D_REGS_SM]
    max_blocks = d[0, D_MAX_BLOCKS]
    warp = d[0, D_WARP]
    rug_seed = d[0, D_RUG_SEED]
    rug_amp = d[0, D_RUG_AMP]

    # Occupancy: resident blocks per SM under each resource limit.
    occ_threads = jnp.floor(max_threads / jnp.maximum(tpb, 1.0))
    occ_smem = jnp.floor(smem_sm / jnp.maximum(smem, 1.0))
    occ_regs = jnp.floor(regs_sm / jnp.maximum(regs * tpb, 1.0))
    occ_blocks = jnp.minimum(
        jnp.minimum(occ_threads, occ_smem), jnp.minimum(occ_regs, max_blocks)
    )

    warp_ok = jnp.floor(tpb / warp) * warp == tpb
    valid = (occ_blocks >= 1.0) & (tpb <= MAX_TPB) & (tpb >= warp) & warp_ok

    occupancy = jnp.minimum(occ_blocks * tpb / max_threads, 1.0)

    vec_bonus = 1.0 - 0.08 * jnp.abs(jnp.log2(jnp.maximum(vecw, 1.0)) - 1.5)
    unroll_curve = 1.0 - 0.05 * jnp.abs(jnp.log2(jnp.maximum(unroll, 1.0)) - 2.0)
    eff_compute = jnp.clip(
        (0.45 + 0.55 * occupancy) * vec_bonus * unroll_curve, 0.05, 1.0
    )
    eff_memory = jnp.clip(
        (0.55 + 0.45 * jnp.sqrt(occupancy))
        * (0.6 + 0.4 * coal)
        * (1.0 + 0.15 * cache),
        0.05,
        1.05,
    )

    t_compute = flops / (peak * eff_compute)
    t_memory = bytes_rw / (bandwidth * eff_memory)

    resident = jnp.maximum(occ_blocks * num_sm, 1.0)
    waves = jnp.ceil(blocks / resident)
    wave_penalty = waves * resident / jnp.maximum(blocks, 1.0)

    u = hash_a * (1.0 - rug_seed) + hash_b * rug_seed
    rugged = 1.0 + rug_amp * (2.0 * u - 1.0)

    t = (
        jnp.maximum(t_compute, t_memory) * wave_penalty * rugged
        + LAUNCH_OVERHEAD * waves
    )
    o_ref[...] = jnp.where(valid, t, INVALID_TIME).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def predict_times(features, device, *, block_n=BLOCK_N):
    """Batched device-model evaluation via pallas_call.

    Args:
      features: f32[N, NUM_FEATURES]; N must be a multiple of block_n
        (the Rust runtime pads batches to the artifact's batch size).
      device:   f32[NUM_DEVICE].

    Returns:
      f32[N] predicted times in seconds (INVALID_TIME for unlaunchable
      configurations).
    """
    n = features.shape[0]
    if n % block_n != 0:
        raise ValueError(f"batch size {n} not a multiple of block_n={block_n}")
    if features.shape[1] != NUM_FEATURES:
        raise ValueError(f"expected {NUM_FEATURES} features, got {features.shape[1]}")
    device2d = device.reshape(1, NUM_DEVICE).astype(jnp.float32)
    grid = (n // block_n,)
    return pl.pallas_call(
        _perfmodel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, NUM_FEATURES), lambda i: (i, 0)),
            pl.BlockSpec((1, NUM_DEVICE), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,  # CPU-PJRT execution path; see module docstring
    )(features.astype(jnp.float32), device2d)
