//! Quickstart: auto-tune one kernel on one (simulated) GPU.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core loop a framework user sees: build/load the
//! brute-force cache for a (kernel, device) pair, run an optimization
//! algorithm in simulation mode under a time budget, and compare against
//! the known optimum.

use anyhow::Result;
use std::sync::Arc;
use tunetuner::dataset::hub::{Hub, HUB_SEED};
use tunetuner::kernels;
use tunetuner::methodology::SpaceEval;
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::runner::{Budget, SimulationRunner, Tuning};
use tunetuner::runtime::Engine;
use tunetuner::util::rng::Rng;

fn main() -> Result<()> {
    // 1. The tuning problem: GEMM on the simulated A100.
    let kernel = kernels::kernel_by_name("gemm")?;
    println!("kernel: {} — {}", kernel.name, kernel.problem);
    println!(
        "search space: {} valid configurations (of {} cartesian)",
        kernel.space().len(),
        kernel.space().cartesian_size()
    );

    // 2. The evaluation engine: PJRT artifacts if present, Rust oracle
    //    otherwise — numerically identical either way.
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    println!("engine backend: {:?}", engine.backend());

    // 3. Brute-force cache (built once, then loaded from the hub).
    let hub = Hub::new(Hub::default_root());
    hub.ensure(&["gemm"], &["A100"], Arc::clone(&engine), HUB_SEED)?;
    let cache = hub.load("gemm", "A100")?;
    println!(
        "cache: {} configs, optimum {:.4} ms, {:.1} simulated brute-force hours",
        cache.records.len(),
        cache.optimum() * 1e3,
        cache.bruteforce_seconds / 3600.0
    );

    // 4. The methodology budget: time for random search to reach 95% of
    //    the median->optimum distance.
    let se = SpaceEval::new(kernel.space_arc(), Arc::clone(&cache), 0.95, 50);
    println!("tuning budget: {:.0} simulated seconds", se.budget_seconds);

    // 5. Tune with the genetic algorithm (tuned-default hyperparameters).
    let hp = HyperParams::new()
        .set("method", "uniform")
        .set("popsize", 20i64)
        .set("maxiter", 150i64)
        .set("mutation_chance", 10i64);
    let opt = optimizers::create("genetic_algorithm", &hp)?;
    let mut sim = SimulationRunner::new(kernel.space_arc(), Arc::clone(&cache))?;
    let mut tuning = Tuning::new(&mut sim, Budget::seconds(se.budget_seconds));
    let wall = std::time::Instant::now();
    opt.run(&mut tuning, &mut Rng::new(42));
    let wall = wall.elapsed();
    let trace = tuning.finish();

    let best = trace.best().expect("found nothing");
    let score = tunetuner::util::stats::mean(&se.score_traces(&[trace.clone()]));
    println!(
        "\ngenetic_algorithm: best {:.4} ms after {} unique evaluations \
         ({:.0}s simulated, {:.3} performance score)",
        best * 1e3,
        trace.unique_evals,
        trace.elapsed,
        score
    );
    println!(
        "gap to optimum: {:.2}% — simulation served {} evals in {wall:?} real time",
        (best / cache.optimum() - 1.0) * 100.0,
        sim.lookups,
    );
    Ok(())
}
