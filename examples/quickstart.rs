//! Quickstart: auto-tune one kernel on one (simulated) GPU through the
//! `Campaign` API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the loop a framework user sees: build a campaign over a
//! kernel×device matrix (the brute-force cache is built on demand), run
//! repeated tuning sessions in simulation mode on the persistent worker
//! pool, and read the scored, provenance-carrying result envelope.

use anyhow::Result;
use std::sync::Arc;
use tunetuner::campaign::{Campaign, LogObserver};
use tunetuner::dataset::hub::Hub;
use tunetuner::kernels;
use tunetuner::optimizers::HyperParams;
use tunetuner::runtime::Engine;

fn main() -> Result<()> {
    // 1. The tuning problem: GEMM on the simulated A100.
    let kernel = kernels::kernel_by_name("gemm")?;
    println!("kernel: {} — {}", kernel.name, kernel.problem);
    println!(
        "search space: {} valid configurations (of {} cartesian)",
        kernel.space().len(),
        kernel.space().cartesian_size()
    );

    // 2. The evaluation engine: PJRT artifacts if present, Rust oracle
    //    otherwise — numerically identical either way.
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    println!("engine backend: {:?}", engine.backend());

    // 3. One campaign: the genetic algorithm with tuned-default
    //    hyperparameters, 5 repeats under the methodology budget. The hub
    //    cache for gemm@A100 is brute-forced on first run, then reused.
    let hp = HyperParams::new()
        .set("method", "uniform")
        .set("popsize", 20i64)
        .set("maxiter", 150i64)
        .set("mutation_chance", 10i64);
    let wall = std::time::Instant::now();
    let result = Campaign::new("genetic_algorithm")
        .hyperparams(hp)
        .matrix(&Hub::new(Hub::default_root()), engine, &["gemm"], &["A100"])?
        .repeats(5)
        .seed(42)
        .observer(Arc::new(LogObserver))
        .run()?;
    let wall = wall.elapsed();

    // 4. The result envelope: per-space outcome + Eq. 3 aggregate.
    let space = &result.spaces[0];
    println!(
        "\n{}: budget {:.0}s, optimum {:.4} ms (fingerprint {})",
        space.label,
        space.budget_seconds,
        space.optimum * 1e3,
        space.space_fingerprint
    );
    println!(
        "genetic_algorithm: best {:.4} ms after {:.0} unique evaluations \
         on average ({:.0}s simulated per run, {:.3} performance score)",
        space.best_value * 1e3,
        space.mean_unique_evals,
        result.simulated_seconds / result.repeats as f64,
        result.score()
    );
    println!(
        "gap to optimum: {:.2}% — {:.0}s of simulated tuning served in {wall:?} real time",
        (space.best_value / space.optimum - 1.0) * 100.0,
        result.simulated_seconds,
    );
    Ok(())
}
