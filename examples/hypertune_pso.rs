//! Tuning the tuner: exhaustive hyperparameter tuning of PSO.
//!
//! Reproduces the Section IV-B workflow on a reduced setting: every
//! Table III hyperparameter configuration of particle swarm optimization
//! is scored across training search spaces in simulation mode, the
//! sensitivity of each hyperparameter is screened (Kruskal–Wallis +
//! mutual information, the screen that dropped `W` in the paper), and the
//! best configuration is validated on a held-out test space.

use anyhow::Result;
use std::sync::Arc;
use tunetuner::dataset::hub::{Hub, HUB_SEED};
use tunetuner::hypertuning::{self, sensitivity::sensitivity};
use tunetuner::kernels;
use tunetuner::methodology::{evaluate_algorithm, SpaceEval};
use tunetuner::optimizers::HyperParams;
use tunetuner::runtime::Engine;

fn main() -> Result<()> {
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    let hub = Hub::new(Hub::default_root());

    // Train on convolution+dedispersion over two devices; test on a third.
    let kernels_used = ["convolution", "dedispersion"];
    let train_devices = ["A100", "MI250X"];
    let test_device = "W7800";
    let mut all_devices = train_devices.to_vec();
    all_devices.push(test_device);
    hub.ensure(&kernels_used, &all_devices, Arc::clone(&engine), HUB_SEED)?;

    let space_eval = |k: &str, d: &str| -> Result<SpaceEval> {
        let kernel = kernels::kernel_by_name(k)?;
        Ok(SpaceEval::new(kernel.space_arc(), hub.load(k, d)?, 0.95, 30))
    };
    let mut train = Vec::new();
    for k in kernels_used {
        for d in train_devices {
            train.push(space_eval(k, d)?);
        }
    }
    let test: Vec<SpaceEval> = kernels_used
        .iter()
        .map(|k| space_eval(k, test_device))
        .collect::<Result<_>>()?;

    // Exhaustive sweep of the Table III PSO space (81 configurations).
    let hp_space = hypertuning::limited_space("pso")?;
    println!(
        "exhaustively tuning PSO: {} hyperparameter configs x {} spaces x 10 repeats",
        hp_space.len(),
        train.len()
    );
    let results =
        hypertuning::exhaustive_tuning("pso", &hp_space, "limited", &train, 10, 7)?;

    println!("\nbest:  {:.3}  {}", results.best().score, results.best().hp_key);
    println!(
        "mean:  {:.3}  {}",
        results.most_average().score,
        results.most_average().hp_key
    );
    println!("worst: {:.3}  {}", results.worst().score, results.worst().hp_key);

    // Sensitivity screen.
    println!("\nhyperparameter sensitivity (Kruskal-Wallis / mutual information):");
    for s in sensitivity(&results, &hp_space) {
        println!(
            "  {:<10} H={:>7.2}  p={:<8.4} MI={:.4}{}",
            s.param,
            s.h,
            s.p,
            s.mutual_information,
            if s.p > 0.05 { "   <- no meaningful effect" } else { "" }
        );
    }

    // Generalization: best vs most-average config on the held-out device.
    let best_hp = HyperParams::from_space_config(&hp_space, results.best().config_idx);
    let avg_hp =
        HyperParams::from_space_config(&hp_space, results.most_average().config_idx);
    let best_test = evaluate_algorithm("pso", &best_hp, &test, 25, 11)?;
    let avg_test = evaluate_algorithm("pso", &avg_hp, &test, 25, 11)?;
    println!(
        "\nheld-out {test_device}: best-config score {:.3} vs average-config {:.3} ({:+.0}%)",
        best_test.score,
        avg_test.score,
        (best_test.score - avg_test.score) / avg_test.score.abs().max(1e-9) * 100.0
    );
    Ok(())
}
