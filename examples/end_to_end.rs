//! End-to-end driver: the full "Tuning the Tuner" pipeline on the real
//! (simulated-hardware) workload, proving all layers compose:
//!
//!   L1/L2 AOT artifacts -> PJRT engine -> brute-force hub (24 spaces)
//!   -> simulation mode -> exhaustive hyperparameter tuning on the
//!   12 training spaces (campaigns on the persistent executor)
//!   -> generalization to the 12 test spaces via `Campaign` re-evaluation
//!   -> headline metrics (improvement %, live-vs-sim speedup).
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end. Runtime is a
//! few minutes on a laptop-class CPU; pass --full for paper-scale repeats.

use anyhow::Result;
use std::sync::Arc;
use tunetuner::campaign::Campaign;
use tunetuner::dataset::hub::{Hub, HUB_SEED};
use tunetuner::gpu::specs::{TEST_DEVICES, TRAIN_DEVICES};
use tunetuner::hypertuning::{exhaustive_tuning, limited_algos, limited_space};
use tunetuner::kernels;
use tunetuner::methodology::SpaceEval;
use tunetuner::optimizers::HyperParams;
use tunetuner::runtime::Engine;
use tunetuner::util::stats;

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (tuning_repeats, eval_repeats, points) = if full { (25, 100, 50) } else { (5, 20, 30) };
    let t_start = std::time::Instant::now();

    // ---- Stage 1: artifacts + engine (L1/L2 -> runtime) --------------------
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    println!("[1] engine: {:?} backend", engine.backend());

    // ---- Stage 2: the benchmark hub (24 brute-forced spaces) ---------------
    let hub = Hub::new(Hub::default_root());
    let entries = hub.ensure_all(Arc::clone(&engine), HUB_SEED)?;
    let total_bf_hours: f64 = entries.iter().map(|(_, _, s)| s / 3600.0).sum();
    println!(
        "[2] hub: {} spaces, {:.0} simulated brute-force hours total",
        entries.len(),
        total_bf_hours
    );

    // ---- Stage 3: prepared train/test spaces --------------------------------
    let prep = |devices: &[&str]| -> Result<Arc<Vec<SpaceEval>>> {
        let mut out = Vec::new();
        for k in ["dedispersion", "convolution", "hotspot", "gemm"] {
            let kernel = kernels::kernel_by_name(k)?;
            for d in devices {
                out.push(SpaceEval::new(
                    kernel.space_arc(),
                    hub.load(k, d)?,
                    0.95,
                    points,
                ));
            }
        }
        Ok(Arc::new(out))
    };
    let train = prep(&TRAIN_DEVICES)?;
    let test = prep(&TEST_DEVICES)?;
    println!(
        "[3] {} training + {} test spaces, budgets {:.0}..{:.0}s",
        train.len(),
        test.len(),
        train.iter().map(|s| s.budget_seconds).fold(f64::INFINITY, f64::min),
        train.iter().map(|s| s.budget_seconds).fold(0.0, f64::max),
    );

    // ---- Stage 4: exhaustive hyperparameter tuning (Eq. 4) ------------------
    let mut improvements_pct = Vec::new();
    let mut test_improvements_pct = Vec::new();
    let mut sim_wallclock = 0.0;
    let mut live_estimate = 0.0;
    let budget_sum: f64 = train.iter().map(|s| s.budget_seconds).sum();
    for algo in limited_algos() {
        let hp_space = limited_space(algo)?;
        let results =
            exhaustive_tuning(algo, &hp_space, "limited", &train, tuning_repeats, 42)?;
        sim_wallclock += results.wallclock_seconds;
        live_estimate += budget_sum * hp_space.len() as f64 * tuning_repeats as f64;

        // ---- Stage 5: re-evaluate best vs most-average on train + test ------
        // One campaign per (hyperparameter assignment, split), all sharing
        // the prepared spaces and the process-wide executor pool.
        let best_hp = HyperParams::from_space_config(&hp_space, results.best().config_idx);
        let avg_hp =
            HyperParams::from_space_config(&hp_space, results.most_average().config_idx);
        let campaign = |spaces: &Arc<Vec<SpaceEval>>, hp: &HyperParams, seed: u64| {
            Campaign::new(algo)
                .hyperparams(hp.clone())
                .spaces_arc(Arc::clone(spaces))
                .repeats(eval_repeats)
                .seed(seed)
                .run()
                .map(|r| r.score())
        };
        let best_all = campaign(&train, &best_hp, 7)?;
        let avg_all = campaign(&train, &avg_hp, 7)?;
        let best_test = campaign(&test, &best_hp, 9)?;
        let avg_test = campaign(&test, &avg_hp, 9)?;
        let pct = |b: f64, a: f64| (b - a) / a.abs().max(1e-9) * 100.0;
        improvements_pct.push(pct(best_all, avg_all));
        test_improvements_pct.push(pct(best_test, avg_test));
        println!(
            "[4] {algo:<22} best {} | train {:.3} -> {:.3} | test {:.3} -> {:.3}",
            results.best().hp_key,
            avg_all,
            best_all,
            avg_test,
            best_test
        );
    }

    // ---- Stage 6: headline metrics -------------------------------------------
    println!("\n=== headline metrics ===");
    println!(
        "average improvement of tuned-optimal over average hyperparameters: {:.1}% \
         (paper: 94.8%)",
        stats::mean(&improvements_pct)
    );
    println!(
        "held-out test-set improvement: {:.1}% (generalization holds: {})",
        stats::mean(&test_improvements_pct),
        stats::mean(&test_improvements_pct) > 0.0
    );
    println!(
        "hyperparameter tuning cost: {:.1}s wall-clock in simulation mode vs \
         {:.0} hours estimated live -> {:.0}x speedup (paper: ~130x)",
        sim_wallclock,
        live_estimate / 3600.0,
        live_estimate / sim_wallclock.max(1e-9)
    );
    println!("total end-to-end runtime: {:.1}s", t_start.elapsed().as_secs_f64());
    Ok(())
}
