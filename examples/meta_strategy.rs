//! Meta-strategies: using an optimizer to tune another optimizer's
//! hyperparameters (Section IV-C), live — no exhaustive sweep.
//!
//! Dual annealing drives the search over simulated annealing's *extended*
//! (Table IV) hyperparameter space; each meta-evaluation runs a repeated
//! simulated tuning campaign. Compares the meta-found configuration
//! against random hyperparameter search with the same meta-budget.

use anyhow::Result;
use std::sync::Arc;
use tunetuner::dataset::hub::{Hub, HUB_SEED};
use tunetuner::hypertuning::{extended_space, MetaRunner};
use tunetuner::kernels;
use tunetuner::methodology::SpaceEval;
use tunetuner::optimizers::{self, HyperParams};
use tunetuner::runner::{Budget, Tuning};
use tunetuner::runtime::Engine;
use tunetuner::util::rng::Rng;

fn main() -> Result<()> {
    let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
    let hub = Hub::new(Hub::default_root());
    hub.ensure(
        &["hotspot", "gemm"],
        &["A100", "A4000"],
        Arc::clone(&engine),
        HUB_SEED,
    )?;

    let mut train = Vec::new();
    for k in ["hotspot", "gemm"] {
        for d in ["A100", "A4000"] {
            let kernel = kernels::kernel_by_name(k)?;
            train.push(SpaceEval::new(kernel.space_arc(), hub.load(k, d)?, 0.95, 25));
        }
    }

    let target = "simulated_annealing";
    let hp_space = Arc::new(extended_space(target)?);
    println!(
        "extended hyperparameter space of {target}: {} configurations",
        hp_space.len()
    );

    let meta_budget = 25; // hyperparameter evaluations per meta-strategy
    for meta_algo in ["dual_annealing", "random_search"] {
        let mut runner = MetaRunner::new(
            target,
            Arc::clone(&hp_space),
            train.clone(),
            8, // repeats per hyperparameter evaluation
            13,
        );
        let mut tuning = Tuning::new(&mut runner, Budget::evals(meta_budget));
        let opt = optimizers::create(meta_algo, &HyperParams::new())?;
        let t0 = std::time::Instant::now();
        opt.run(&mut tuning, &mut Rng::new(99));
        let trace = tuning.finish();
        let (best_idx, best_score) = runner
            .history
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("no evaluations");
        println!(
            "\nmeta:{meta_algo}: {} hyperparameter evals in {:.1}s real time",
            trace.unique_evals,
            t0.elapsed().as_secs_f64()
        );
        println!(
            "  best found: score {best_score:.3} with {}",
            hp_space.key(best_idx)
        );
    }
    println!(
        "\n(dual annealing should find an equal-or-better configuration than \
         random hyperparameter search at the same meta-budget)"
    );
    Ok(())
}
